//! Transform service: serve NNLS feature-projection requests over TCP
//! with dynamic batching.
//!
//! Once a basis `W` is fitted (offline, possibly at paper scale with the
//! randomized solver), downstream consumers need `transform(y) =
//! argmin_{c≥0} ‖y − Wc‖` at low latency. The service:
//!
//! * accepts length-prefixed binary requests (one `m`-vector each),
//! * **batches** concurrent requests: the solver thread drains whatever
//!   has queued (up to `max_batch`) and runs one batched NNLS solve on a
//!   [`crate::nmf::transform::Transform`] prepared at startup — the Gram
//!   `WᵀW` is computed once for the lifetime of the server and shared
//!   across every batch, and the solver thread's warm
//!   [`TransformScratch`] makes steady-state solves allocation-free,
//! * records queue→reply latency per request into a sliding-window
//!   [`LatencyRecorder`] — [`TransformServer::latency_summary`] exposes
//!   p50/p90/p99/max for dashboards and the serving bench,
//! * responds with the `k`-vector code.
//!
//! Wire format (little-endian): request = `u32 m` + `m×f64`; response =
//! `u32 k` + `k×f64`, or `u32::MAX` + `u32 len` + UTF-8 error message.
//!
//! This is the L3 "request loop" of the architecture: a thin, dependency-
//! free replacement for what tokio+tower would provide.
//!
//! ## Robustness contract
//!
//! The edge is built to survive hostile or broken clients with bounded
//! memory and no thread leaks:
//!
//! * a request's dimension is validated against the served model
//!   **before any allocation** — an absurd length prefix gets an error
//!   reply and the connection is closed (the unread payload makes resync
//!   impossible), while a sane-but-wrong dimension still gets a clean
//!   error reply on a connection that stays usable;
//! * a half-written request that stalls longer than
//!   [`ServerOptions::read_timeout`] is dropped (per-connection write
//!   timeouts bound the reply side the same way);
//! * the solve queue is bounded ([`ServerOptions::max_queue`]): past the
//!   limit, requests are **shed** with an overload error reply instead of
//!   growing memory;
//! * each connection runs under panic isolation, and a panicking batch
//!   solve replies an error to its requests instead of killing the
//!   solver thread;
//! * shutdown drains: queued requests are answered before the solver
//!   thread exits.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::metrics::{LatencyRecorder, LatencySummary};
use crate::linalg::mat::Mat;
use crate::nmf::model::NmfModel;
use crate::nmf::transform::{Transform, TransformOptions, TransformScratch};

/// A queued request: the input vector, the slot for its reply, and when
/// it entered the queue (for latency accounting).
struct Pending {
    input: Vec<f64>,
    reply: std::sync::mpsc::Sender<Result<Vec<f64>, String>>,
    enqueued: Instant,
}

/// Shared server state.
struct Shared {
    queue: Mutex<Vec<Pending>>,
    wake: Condvar,
    stop: AtomicBool,
    served: AtomicUsize,
    batches: AtomicUsize,
    /// Requests rejected because the queue was at `max_queue`.
    shed: AtomicUsize,
    /// Queue→reply latency of recently answered requests.
    latency: Mutex<LatencyRecorder>,
}

/// Record one answered request's queue→reply latency.
fn note_latency(shared: &Shared, enqueued: Instant) {
    let mut rec = shared.latency.lock().unwrap_or_else(|e| e.into_inner());
    rec.record(enqueued.elapsed().as_secs_f64());
}

/// Configuration of the transform service.
#[derive(Clone, Debug)]
pub struct ServerOptions {
    /// Max requests fused into one batched solve.
    pub max_batch: usize,
    /// How long the solver waits to accumulate a batch.
    pub batch_window: Duration,
    /// HALS-NNLS sweeps per solve.
    pub nnls_sweeps: usize,
    /// Longest a request may stall mid-message before its connection is
    /// dropped (a half-written request cannot pin a thread forever).
    pub read_timeout: Duration,
    /// Per-connection socket write timeout for replies.
    pub write_timeout: Duration,
    /// Bound on queued requests; past it new requests are shed with an
    /// overload error reply, keeping server memory bounded under flood.
    pub max_queue: usize,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            max_batch: 64,
            batch_window: Duration::from_millis(2),
            nnls_sweeps: 60,
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            max_queue: 1024,
        }
    }
}

/// Handle to a running server (owns the listener thread).
pub struct TransformServer {
    addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl TransformServer {
    /// Start serving `model` on `addr` (use port 0 for an OS-chosen port).
    pub fn start(addr: &str, model: NmfModel, opts: ServerOptions) -> Result<TransformServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            wake: Condvar::new(),
            stop: AtomicBool::new(false),
            served: AtomicUsize::new(0),
            batches: AtomicUsize::new(0),
            shed: AtomicUsize::new(0),
            latency: Mutex::new(LatencyRecorder::default()),
        });
        // Freeze the basis once: the Gram is precomputed here and every
        // batch for the server's lifetime reuses it. Rejects degenerate
        // bases (empty, negative entries) before any thread spawns.
        let topts = TransformOptions::default().with_sweeps(opts.nnls_sweeps);
        let transform =
            Transform::new(model.w.clone(), topts).context("preparing the serving basis")?;
        let model_m = transform.rows();

        let mut threads = Vec::new();

        // Solver thread: drains the queue into batched NNLS solves.
        {
            let shared = shared.clone();
            let opts = opts.clone();
            threads.push(std::thread::spawn(move || solver_loop(&shared, &transform, &opts)));
        }

        // Accept loop: one lightweight thread per connection. Connection
        // threads are *not* joined — they idle on a short read timeout and
        // exit on their own once `stop` is set or the peer disconnects.
        // Each runs under `catch_unwind`, so a handler bug on one
        // connection can never take down a sibling or the accept loop.
        {
            let shared = shared.clone();
            threads.push(std::thread::spawn(move || {
                while !shared.stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let shared = shared.clone();
                            let conn_opts = opts.clone();
                            std::thread::spawn(move || {
                                let _ = catch_unwind(AssertUnwindSafe(|| {
                                    let _ = handle_conn(stream, &shared, model_m, &conn_opts);
                                }));
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        Err(_) => break,
                    }
                }
            }));
        }

        Ok(TransformServer { addr: local, shared, threads })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Total requests served and batches executed (batching efficiency =
    /// served / batches).
    pub fn stats(&self) -> (usize, usize) {
        (self.shared.served.load(Ordering::Relaxed), self.shared.batches.load(Ordering::Relaxed))
    }

    /// Requests shed with an overload reply because the queue was full.
    pub fn shed_count(&self) -> usize {
        self.shared.shed.load(Ordering::Relaxed)
    }

    /// Queue→answer latency percentiles over the recent request window
    /// (enqueue to solve completion; `count` is lifetime-total and
    /// statistics are NaN before the first answered request). Noted
    /// before the reply is sent, so a client holding its answer is
    /// always visible here.
    pub fn latency_summary(&self) -> LatencySummary {
        self.shared.latency.lock().unwrap_or_else(|e| e.into_inner()).summary()
    }

    /// Signal shutdown, drain, and join all threads.
    ///
    /// The solver thread answers everything already queued before it
    /// exits (graceful drain), so no accepted request is silently
    /// dropped; connection threads observe `stop` at their next idle
    /// poll and unwind on their own.
    pub fn shutdown(self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        self.shared.wake.notify_all();
        for t in self.threads {
            let _ = t.join();
        }
    }
}

fn solver_loop(shared: &Shared, transform: &Transform, opts: &ServerOptions) {
    let m = transform.rows();
    let k = transform.rank();
    // Warm per-thread state: after the first few batches every solve
    // draws all of its buffers from this scratch pool and the reused
    // batch panel, so the steady-state hot path allocates only the reply
    // vectors that leave the thread.
    let mut scratch = TransformScratch::new();
    let mut y = Mat::zeros(1, 1);

    loop {
        // Wait for work (or stop).
        let mut batch: Vec<Pending> = {
            let guard = shared.queue.lock().unwrap_or_else(|e| e.into_inner());
            let (mut guard, _) = shared
                .wake
                .wait_timeout_while(guard, Duration::from_millis(50), |q| {
                    q.is_empty() && !shared.stop.load(Ordering::Relaxed)
                })
                .unwrap_or_else(|e| e.into_inner());
            if shared.stop.load(Ordering::Relaxed) && guard.is_empty() {
                return;
            }
            if guard.is_empty() {
                continue;
            }
            // Short accumulation window for better batching.
            drop(guard);
            std::thread::sleep(opts.batch_window);
            guard = shared.queue.lock().unwrap_or_else(|e| e.into_inner());
            let take = guard.len().min(opts.max_batch);
            guard.drain(..take).collect()
        };
        if batch.is_empty() {
            continue;
        }
        shared.batches.fetch_add(1, Ordering::Relaxed);
        shared.served.fetch_add(batch.len(), Ordering::Relaxed);

        // Validate inputs, assemble Y (m×b). Dimension and finiteness are
        // checked per request — one bad request gets its own error reply
        // and cannot poison the batch it rode in with.
        let mut valid = Vec::new();
        for p in batch.drain(..) {
            // Latency is noted *before* the reply is sent, so a client
            // that has its answer is guaranteed to be in the recorder.
            if p.input.len() != m {
                note_latency(shared, p.enqueued);
                let _ = p
                    .reply
                    .send(Err(format!("expected {m}-dim input, got {}", p.input.len())));
            } else if p.input.iter().any(|v| !v.is_finite()) {
                note_latency(shared, p.enqueued);
                let _ = p.reply.send(Err("input contains NaN/Inf".to_string()));
            } else {
                valid.push(p);
            }
        }
        if valid.is_empty() {
            continue;
        }
        let b = valid.len();

        // Batched NNLS on the frozen basis: the precomputed Gram and the
        // warm scratch are shared across the whole batch. The solve runs
        // under `catch_unwind` — a panicking batch replies errors
        // instead of killing the solver thread (and the service with it).
        y.resize(m, b); // flat resize; every column is overwritten below
        for (j, p) in valid.iter().enumerate() {
            y.set_col(j, &p.input);
        }
        let solved =
            catch_unwind(AssertUnwindSafe(|| transform.transform_with(&y, &mut scratch)));
        match solved {
            Ok(Ok(h)) => {
                // h is k×b: reply column j to request j, then hand the
                // panel back to the pool for the next batch.
                for (j, p) in valid.into_iter().enumerate() {
                    let code: Vec<f64> = (0..k).map(|i| h.get(i, j)).collect();
                    note_latency(shared, p.enqueued);
                    let _ = p.reply.send(Ok(code));
                }
                scratch.recycle(h);
            }
            Ok(Err(e)) => {
                // Unreachable given per-request validation above, but a
                // refused batch still answers rather than hanging clients.
                let msg = e.to_string();
                for p in valid {
                    note_latency(shared, p.enqueued);
                    let _ = p.reply.send(Err(msg.clone()));
                }
            }
            Err(payload) => {
                let msg = format!(
                    "batch solve panicked: {}",
                    crate::coordinator::scheduler::panic_message(payload)
                );
                for p in valid {
                    note_latency(shared, p.enqueued);
                    let _ = p.reply.send(Err(msg.clone()));
                }
            }
        }
    }
}

/// Write the wire-format error reply (`u32::MAX` + length + UTF-8 text).
fn send_error(w: &mut impl Write, msg: &str) -> Result<()> {
    w.write_all(&u32::MAX.to_le_bytes())?;
    w.write_all(&(msg.len() as u32).to_le_bytes())?;
    w.write_all(msg.as_bytes())?;
    Ok(())
}

fn handle_conn(
    stream: TcpStream,
    shared: &Shared,
    model_m: usize,
    opts: &ServerOptions,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Idle reads wake every 100 ms to observe `stop` (otherwise a
    // connected-but-silent client would pin this thread past shutdown).
    stream.set_read_timeout(Some(Duration::from_millis(100))).ok();
    stream.set_write_timeout(Some(opts.write_timeout)).ok();
    // A request larger than any plausible input for this model is
    // rejected *before* its payload is allocated or read — per-connection
    // memory stays O(model m) no matter what the length prefix claims.
    let wire_cap = model_m.saturating_mul(4).max(4096);
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    // Payload buffer hoisted out of the request loop: a chatty
    // connection reuses one allocation sized to its largest request.
    let mut data: Vec<u8> = Vec::new();
    loop {
        // Request: u32 m + m f64s. Clean EOF ends the connection.
        let mut len_buf = [0u8; 4];
        match reader.read_exact(&mut len_buf) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(()),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if shared.stop.load(Ordering::Relaxed) {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        let m = u32::from_le_bytes(len_buf) as usize;
        if m > wire_cap {
            // The oversized payload will never be read, so the stream
            // cannot be resynced: reply with the reason, then close.
            send_error(
                &mut writer,
                &format!("request dimension {m} exceeds server limit {wire_cap}"),
            )?;
            writer.flush()?;
            anyhow::bail!("oversized request dimension {m} (limit {wire_cap})");
        }
        data.clear();
        data.resize(m * 8, 0);
        // The payload may arrive across several packets; resume across
        // read timeouts (unlike `read_exact`, which cannot) but give up
        // once the peer stalls mid-message for longer than the deadline.
        read_exact_retry(&mut reader, &mut data, shared, opts.read_timeout)?;
        let input: Vec<f64> = data
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();

        let (tx, rx) = std::sync::mpsc::channel();
        let enqueued = {
            let mut q = shared.queue.lock().unwrap_or_else(|e| e.into_inner());
            if q.len() >= opts.max_queue {
                false
            } else {
                q.push(Pending { input, reply: tx, enqueued: Instant::now() });
                true
            }
        };
        if !enqueued {
            // Overload shedding: bounded queue, explicit signal, and the
            // connection stays usable for a later retry.
            shared.shed.fetch_add(1, Ordering::Relaxed);
            send_error(&mut writer, "server overloaded: queue full, retry later")?;
            writer.flush()?;
            continue;
        }
        shared.wake.notify_one();

        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(Ok(code)) => {
                writer.write_all(&(code.len() as u32).to_le_bytes())?;
                for v in code {
                    writer.write_all(&v.to_le_bytes())?;
                }
            }
            Ok(Err(msg)) => send_error(&mut writer, &msg)?,
            Err(_) => anyhow::bail!("solver timeout"),
        }
        writer.flush()?;
    }
}

/// `read_exact` that survives read timeouts (resumes where it left off),
/// aborts on shutdown, and drops a peer that stalls mid-message for
/// longer than `stall_limit` without sending a byte.
fn read_exact_retry(
    r: &mut impl Read,
    buf: &mut [u8],
    shared: &Shared,
    stall_limit: Duration,
) -> Result<()> {
    let mut filled = 0;
    let mut last_progress = std::time::Instant::now();
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => anyhow::bail!("peer closed mid-message"),
            Ok(n) => {
                filled += n;
                last_progress = std::time::Instant::now();
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if shared.stop.load(Ordering::Relaxed) {
                    anyhow::bail!("server stopping");
                }
                if last_progress.elapsed() >= stall_limit {
                    anyhow::bail!(
                        "request stalled mid-message for {:.1}s, dropping connection",
                        stall_limit.as_secs_f64()
                    );
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Blocking client for the wire protocol (used by tests, benches and the
/// CLI).
pub struct TransformClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl TransformClient {
    pub fn connect(addr: std::net::SocketAddr) -> Result<TransformClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(TransformClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    /// Send one vector; receive its nonnegative code.
    pub fn transform(&mut self, y: &[f64]) -> Result<Vec<f64>> {
        self.writer.write_all(&(y.len() as u32).to_le_bytes())?;
        for v in y {
            self.writer.write_all(&v.to_le_bytes())?;
        }
        self.writer.flush()?;
        let mut len_buf = [0u8; 4];
        self.reader.read_exact(&mut len_buf)?;
        let k = u32::from_le_bytes(len_buf);
        if k == u32::MAX {
            self.reader.read_exact(&mut len_buf)?;
            let n = u32::from_le_bytes(len_buf) as usize;
            let mut msg = vec![0u8; n];
            self.reader.read_exact(&mut msg)?;
            anyhow::bail!("server error: {}", String::from_utf8_lossy(&msg));
        }
        let mut data = vec![0u8; k as usize * 8];
        self.reader.read_exact(&mut data)?;
        Ok(data
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm;
    use crate::linalg::rng::Pcg64;

    fn test_model(m: usize, k: usize, seed: u64) -> NmfModel {
        let mut rng = Pcg64::seed_from_u64(seed);
        NmfModel { w: rng.uniform_mat(m, k).map(|v| v + 0.05), h: Mat::zeros(k, 1) }
    }

    #[test]
    fn serves_correct_codes() {
        let model = test_model(24, 4, 1);
        let w = model.w.clone();
        let server =
            TransformServer::start("127.0.0.1:0", model, ServerOptions::default()).unwrap();
        let mut client = TransformClient::connect(server.addr()).unwrap();

        let mut rng = Pcg64::seed_from_u64(2);
        let c_true: Vec<f64> = (0..4).map(|_| rng.uniform() + 0.1).collect();
        let y = gemm::matvec(&w, &c_true);
        let code = client.transform(&y).unwrap();
        assert_eq!(code.len(), 4);
        // Reconstruction matches even if the code itself is a different
        // NNLS solution.
        let rec = gemm::matvec(&w, &code);
        let err: f64 = rec
            .iter()
            .zip(y.iter())
            .map(|(a, b)| (a - b).powi(2))
            .sum::<f64>()
            .sqrt()
            / y.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(err < 1e-4, "reconstruction err {err}");
        assert!(code.iter().all(|&v| v >= 0.0));
        let lat = server.latency_summary();
        assert_eq!(lat.count, 1);
        assert!(lat.p50.is_finite() && lat.p50 >= 0.0, "p50 {}", lat.p50);
        assert_eq!(lat.max, lat.p50, "single sample: every percentile is that sample");
        server.shutdown();
    }

    #[test]
    fn wrong_dimension_gets_error_reply() {
        let model = test_model(10, 3, 3);
        let server =
            TransformServer::start("127.0.0.1:0", model, ServerOptions::default()).unwrap();
        let mut client = TransformClient::connect(server.addr()).unwrap();
        let err = client.transform(&[1.0, 2.0]).unwrap_err().to_string();
        assert!(err.contains("expected 10-dim"), "{err}");
        // Connection still usable afterwards.
        let ok = client.transform(&vec![0.5; 10]).unwrap();
        assert_eq!(ok.len(), 3);
        server.shutdown();
    }

    #[test]
    fn concurrent_clients_get_batched() {
        let model = test_model(16, 3, 4);
        let w = model.w.clone();
        let opts = ServerOptions {
            max_batch: 32,
            batch_window: Duration::from_millis(10),
            nnls_sweeps: 40,
            ..ServerOptions::default()
        };
        let server = TransformServer::start("127.0.0.1:0", model, opts).unwrap();
        let addr = server.addr();

        let nreq = 24;
        let w = &w;
        std::thread::scope(|s| {
            for t in 0..nreq {
                s.spawn(move || {
                    let mut client = TransformClient::connect(addr).unwrap();
                    let mut rng = Pcg64::seed_from_u64(100 + t as u64);
                    let c: Vec<f64> = (0..3).map(|_| rng.uniform() + 0.1).collect();
                    let y = gemm::matvec(&w, &c);
                    let code = client.transform(&y).unwrap();
                    let rec = gemm::matvec(&w, &code);
                    let err: f64 = rec
                        .iter()
                        .zip(y.iter())
                        .map(|(a, b)| (a - b).powi(2))
                        .sum::<f64>()
                        .sqrt();
                    assert!(err < 1e-3 * y.len() as f64, "err {err}");
                });
            }
        });
        let (served, batches) = server.stats();
        assert_eq!(served, nreq);
        assert!(
            batches < nreq,
            "dynamic batching should fuse requests: {served} served in {batches} batches"
        );
        server.shutdown();
    }
}

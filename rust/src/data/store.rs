//! `.nmfstore` — the column-blocked on-disk matrix store (dense and
//! sparse).
//!
//! The paper's out-of-core discussion (Appendix A) assumes an HDF5-style
//! container that can hand back subsets of columns without touching the
//! rest of the file. This is our substitute: a flat binary format whose
//! unit of I/O is a **column block**, so the blocked QB algorithm streams
//! `2 + 2q` sequential passes with `O(m·block)` memory.
//!
//! Dense layout (little-endian):
//!
//! ```text
//! magic    8 bytes  "NMFSTOR1"
//! rows     u64
//! cols     u64
//! block    u64                  column-block width
//! data     ⌈cols/block⌉ blocks, each a rows×bw row-major f64 slab
//! ```
//!
//! Sparse (CSC-slab) extension — [`SparseNmfStore`] — stores the matrix
//! column-major so any column range is one contiguous byte range and a
//! streaming pass costs `O(nnz)` I/O instead of `O(m·n)`:
//!
//! ```text
//! magic    8 bytes  "NMFSPRS1"
//! rows     u64
//! cols     u64
//! block    u64                  column-slab width (metadata)
//! nnz      u64                  total stored entries
//! colptr   (cols+1) × u64       absolute entry offset per column
//! payload  nnz entries, each {row u64, value f64} ascending-row per col
//! ```
//!
//! `colptr` is loaded at open (`O(cols)` resident — 8 MB per million
//! columns), after which reading columns `[j0, j1)` is exactly one
//! `pread` of `16·(colptr[j1] − colptr[j0])` bytes plus an in-place
//! decode into the caller's reusable
//! [`CscBlock`](crate::sketch::blocked::CscBlock) — zero steady-state
//! allocations, the contract [`qb_blocked_sparse_with`] relies on.
//!
//! [`qb_blocked_sparse_with`]: crate::sketch::blocked::qb_blocked_sparse_with
//!
//! Reads use `pread` (`FileExt::read_exact_at`), so a shared store handle
//! can serve concurrent readers without seek races.

use std::fs::File;
use std::io::Write;
use std::os::unix::fs::FileExt;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::linalg::mat::Mat;
use crate::linalg::sparse::CscMat;
use crate::sketch::blocked::{ColumnBlockSource, CscBlock, SparseColumnBlockSource};

const MAGIC: &[u8; 8] = b"NMFSTOR1";
const SPARSE_MAGIC: &[u8; 8] = b"NMFSPRS1";

/// Read handle for a `.nmfstore` file.
pub struct NmfStore {
    file: File,
    rows: usize,
    cols: usize,
    block: usize,
    /// Reusable slab staging for `read_block_into`'s misaligned path:
    /// grown once to the native slab size, then reused, so the
    /// out-of-core reader performs one `pread` per slab and zero
    /// steady-state allocations. Behind a mutex because reads take
    /// `&self`; only the blocked-QB driver (single-threaded) uses it, so
    /// contention is nil and `read_cols`' concurrent readers are
    /// unaffected (they allocate their own slabs as before).
    slab_scratch: Mutex<Vec<f64>>,
}

impl NmfStore {
    /// Open an existing store.
    pub fn open(path: &Path) -> Result<NmfStore> {
        let file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let mut header = [0u8; 32];
        file.read_exact_at(&mut header, 0).context("reading header")?;
        if &header[0..8] != MAGIC {
            bail!("{} is not an nmfstore file", path.display());
        }
        let rows = u64::from_le_bytes(header[8..16].try_into().unwrap()) as usize;
        let cols = u64::from_le_bytes(header[16..24].try_into().unwrap()) as usize;
        let block = u64::from_le_bytes(header[24..32].try_into().unwrap()) as usize;
        if block == 0 || rows == 0 || cols == 0 {
            bail!("degenerate store dimensions {rows}x{cols} block {block}");
        }
        Ok(NmfStore { file, rows, cols, block, slab_scratch: Mutex::new(Vec::new()) })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Native block width (reads at this granularity are single-slab).
    pub fn block_width(&self) -> usize {
        self.block
    }

    /// Byte offset of block `bi` (blocks before it are all full except
    /// possibly none — only the last block is short).
    fn block_offset(&self, bi: usize) -> u64 {
        32 + (bi * self.block * self.rows * 8) as u64
    }

    fn block_cols_of(&self, bi: usize) -> usize {
        let j0 = bi * self.block;
        (self.cols - j0).min(self.block)
    }

    /// Read one whole native block as a rows×bw matrix.
    pub fn read_native_block(&self, bi: usize) -> Result<Mat> {
        let bw = self.block_cols_of(bi);
        anyhow::ensure!(bw > 0, "block index {bi} out of range");
        let nbytes = self.rows * bw * 8;
        let mut buf = vec![0u8; nbytes];
        self.file
            .read_exact_at(&mut buf, self.block_offset(bi))
            .with_context(|| format!("reading block {bi}"))?;
        let data: Vec<f64> = buf
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Mat::from_vec(self.rows, bw, data))
    }

    /// Read an arbitrary column range `[j0, j1)` (slices native blocks).
    pub fn read_cols(&self, j0: usize, j1: usize) -> Result<Mat> {
        anyhow::ensure!(j0 < j1 && j1 <= self.cols, "bad column range {j0}..{j1}");
        let mut out = Mat::zeros(self.rows, j1 - j0);
        let mut bi = j0 / self.block;
        loop {
            let b0 = bi * self.block;
            if b0 >= j1 {
                break;
            }
            let blk = self.read_native_block(bi)?;
            let lo = j0.max(b0);
            let hi = j1.min(b0 + blk.cols());
            let piece = blk.col_block(lo - b0, hi - b0);
            out.set_col_block(lo - j0, &piece);
            bi += 1;
        }
        Ok(out)
    }

    /// Materialize the full matrix (small stores / tests only).
    pub fn read_all(&self) -> Result<Mat> {
        self.read_cols(0, self.cols)
    }
}

/// View an `f64` slice as raw little-endian-file bytes for `pread`ing
/// straight into matrix storage (no staging buffer, no allocation).
fn as_bytes_mut(s: &mut [f64]) -> &mut [u8] {
    // SAFETY: f64 and [u8; 8] have no invalid bit patterns; the slice
    // covers exactly the same memory. Callers fix endianness afterwards.
    unsafe { std::slice::from_raw_parts_mut(s.as_mut_ptr() as *mut u8, s.len() * 8) }
}

/// Reinterpret bytes just `pread` into `s` as little-endian `f64`s, in
/// place (no-op on little-endian hosts).
fn fix_le_in_place(s: &mut [f64]) {
    for v in s {
        *v = f64::from_bits(u64::from_le((*v).to_bits()));
    }
}

impl ColumnBlockSource for NmfStore {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    fn read_block(&self, j0: usize, j1: usize) -> Result<Mat> {
        self.read_cols(j0, j1)
    }

    /// Allocation-free block read: a block-aligned range is `pread`
    /// directly into `out`'s storage; a misaligned range reads each
    /// overlapped slab whole into the store's reusable staging buffer and
    /// copies the needed column segments out. Either way: one contiguous
    /// read per slab, endian-fix in place, zero steady-state allocations
    /// once the buffers are warm — what the out-of-core QB path relies on.
    fn read_block_into(&self, j0: usize, j1: usize, out: &mut Mat) -> Result<()> {
        anyhow::ensure!(j0 < j1 && j1 <= self.cols, "bad column range {j0}..{j1}");
        let w = j1 - j0;
        out.resize(self.rows, w);
        // Fast path: the range is exactly one whole native block — the
        // on-disk slab layout matches `out` row-major, one contiguous read.
        if j0 % self.block == 0 && self.block_cols_of(j0 / self.block) == w {
            let bi = j0 / self.block;
            self.file
                .read_exact_at(as_bytes_mut(out.as_mut_slice()), self.block_offset(bi))
                .with_context(|| format!("reading block {bi}"))?;
            fix_le_in_place(out.as_mut_slice());
            return Ok(());
        }
        // General path: one whole-slab `pread` per overlapped native
        // block into the reusable staging buffer, then copy the needed
        // column segments out row by row.
        let mut scratch = self.slab_scratch.lock().unwrap_or_else(|e| e.into_inner());
        let mut bi = j0 / self.block;
        loop {
            let b0 = bi * self.block;
            if b0 >= j1 {
                break;
            }
            let bw = self.block_cols_of(bi);
            let lo = j0.max(b0);
            let hi = j1.min(b0 + bw);
            scratch.resize(self.rows * bw, 0.0);
            self.file
                .read_exact_at(as_bytes_mut(&mut scratch[..]), self.block_offset(bi))
                .with_context(|| format!("reading block {bi}"))?;
            fix_le_in_place(&mut scratch[..]);
            for i in 0..self.rows {
                let src = &scratch[i * bw + (lo - b0)..i * bw + (hi - b0)];
                out.row_mut(i)[lo - j0..hi - j0].copy_from_slice(src);
            }
            bi += 1;
        }
        Ok(())
    }
}

/// Incremental writer: blocks are appended in order, so a generator can
/// stream a matrix to disk without materializing it.
pub struct NmfStoreWriter {
    file: File,
    rows: usize,
    cols: usize,
    block: usize,
    written_cols: usize,
}

impl NmfStoreWriter {
    pub fn create(path: &Path, rows: usize, cols: usize, block: usize) -> Result<NmfStoreWriter> {
        anyhow::ensure!(rows > 0 && cols > 0 && block > 0, "degenerate store shape");
        let mut file =
            File::create(path).with_context(|| format!("creating {}", path.display()))?;
        file.write_all(MAGIC)?;
        file.write_all(&(rows as u64).to_le_bytes())?;
        file.write_all(&(cols as u64).to_le_bytes())?;
        file.write_all(&(block as u64).to_le_bytes())?;
        Ok(NmfStoreWriter { file, rows, cols, block, written_cols: 0 })
    }

    /// Append the next column block. Must be `block` wide except the last.
    pub fn write_block(&mut self, m: &Mat) -> Result<()> {
        anyhow::ensure!(m.rows() == self.rows, "row mismatch");
        let expected = (self.cols - self.written_cols).min(self.block);
        anyhow::ensure!(
            m.cols() == expected,
            "block width {} != expected {expected}",
            m.cols()
        );
        let mut buf = Vec::with_capacity(m.len() * 8);
        for &v in m.as_slice() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.file.write_all(&buf)?;
        self.written_cols += m.cols();
        Ok(())
    }

    /// Finish; errors if the column count is short.
    pub fn finish(mut self) -> Result<()> {
        anyhow::ensure!(
            self.written_cols == self.cols,
            "store incomplete: {}/{} columns written",
            self.written_cols,
            self.cols
        );
        self.file.flush()?;
        Ok(())
    }
}

/// Write an in-memory matrix as a store (tests and small data).
pub fn write_mat(path: &Path, m: &Mat, block: usize) -> Result<()> {
    let mut w = NmfStoreWriter::create(path, m.rows(), m.cols(), block)?;
    let mut j0 = 0;
    while j0 < m.cols() {
        let j1 = (j0 + block).min(m.cols());
        w.write_block(&m.col_block(j0, j1))?;
        j0 = j1;
    }
    w.finish()
}

// ---------------------------------------------------------------------------
// Sparse (CSC-slab) store.
// ---------------------------------------------------------------------------

/// Byte offset of the sparse header (fixed) and derived regions.
const SPARSE_HEADER_BYTES: u64 = 40;
/// Bytes per payload entry: row `u64` + value `f64`.
const ENTRY_BYTES: usize = 16;

/// Read handle for a sparse (CSC-slab) `.nmfstore` file — see the module
/// docs for the layout. Implements
/// [`SparseColumnBlockSource`], so [`crate::sketch::blocked`]'s sparse
/// out-of-core engine streams it directly.
pub struct SparseNmfStore {
    file: File,
    rows: usize,
    cols: usize,
    block: usize,
    nnz: usize,
    /// Absolute per-column entry offsets (`cols + 1` values), loaded at
    /// open — what turns any column-range read into one contiguous
    /// `pread`.
    colptr: Vec<u64>,
    /// Reusable payload staging for `read_block_into` (same pattern as
    /// the dense store's `slab_scratch`): grown to the largest read once,
    /// then reused — one `pread` per range, zero steady-state
    /// allocations. Behind a mutex because reads take `&self`.
    payload_scratch: Mutex<Vec<u8>>,
}

impl SparseNmfStore {
    /// Open an existing sparse store and load its column pointer.
    pub fn open(path: &Path) -> Result<SparseNmfStore> {
        let file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let mut header = [0u8; SPARSE_HEADER_BYTES as usize];
        file.read_exact_at(&mut header, 0).context("reading sparse header")?;
        if &header[0..8] != SPARSE_MAGIC {
            bail!("{} is not a sparse nmfstore file", path.display());
        }
        let rows = u64::from_le_bytes(header[8..16].try_into().unwrap()) as usize;
        let cols = u64::from_le_bytes(header[16..24].try_into().unwrap()) as usize;
        let block = u64::from_le_bytes(header[24..32].try_into().unwrap()) as usize;
        let nnz = u64::from_le_bytes(header[32..40].try_into().unwrap()) as usize;
        if block == 0 || rows == 0 || cols == 0 {
            bail!("degenerate sparse store dimensions {rows}x{cols} block {block}");
        }
        let mut ptr_bytes = vec![0u8; (cols + 1) * 8];
        file.read_exact_at(&mut ptr_bytes, SPARSE_HEADER_BYTES)
            .context("reading column pointer")?;
        let colptr: Vec<u64> = ptr_bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        if colptr[0] != 0 || colptr[cols] as usize != nnz || colptr.windows(2).any(|w| w[0] > w[1])
        {
            bail!("corrupt column pointer in {}", path.display());
        }
        Ok(SparseNmfStore {
            file,
            rows,
            cols,
            block,
            nnz,
            colptr,
            payload_scratch: Mutex::new(Vec::new()),
        })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Total stored entries.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Column-slab width metadata (reads are contiguous at any width; the
    /// value records the writer's streaming granularity for diagnostics).
    pub fn block_width(&self) -> usize {
        self.block
    }

    /// Byte offset where the entry payload begins.
    fn payload_offset(&self) -> u64 {
        SPARSE_HEADER_BYTES + ((self.cols + 1) * 8) as u64
    }

    /// Materialize the full matrix as a [`CscMat`] (small stores /
    /// tests): one streamed decode, assembled column-by-column in the
    /// order the block already holds, validated by
    /// [`CscMat::from_parts`] — a corrupt file is an `Err`, not a panic.
    pub fn read_all(&self) -> Result<CscMat> {
        let mut block = CscBlock::new();
        SparseColumnBlockSource::read_block_into(self, 0, self.cols, &mut block)?;
        let mut indptr = Vec::with_capacity(self.cols + 1);
        indptr.push(0);
        let mut indices = Vec::with_capacity(self.nnz);
        let mut values = Vec::with_capacity(self.nnz);
        for j in 0..self.cols {
            let (is, vs) = block.col(j);
            indices.extend_from_slice(is);
            values.extend_from_slice(vs);
            indptr.push(indices.len());
        }
        CscMat::from_parts(self.rows, self.cols, indptr, indices, values)
    }
}

impl SparseColumnBlockSource for SparseNmfStore {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    fn nnz(&self) -> usize {
        self.nnz
    }

    /// Append columns `[j0, j1)` to `out`: exactly one `pread` of the
    /// contiguous entry range (CSC's gift — no slab alignment cases),
    /// then an in-place decode. Zero steady-state allocations once the
    /// staging buffer and `out` are warm.
    ///
    /// The payload is **validated as it is decoded** — row indices must
    /// be in bounds and strictly ascending per column (the invariants
    /// every downstream kernel indexes by) — so a corrupt or truncated
    /// file surfaces as an `Err` here instead of a panic (or a silent
    /// determinism break) deep inside a compute pass. The `open`-time
    /// check covers only the column pointer; this covers the entries.
    fn read_block_into(&self, j0: usize, j1: usize, out: &mut CscBlock) -> Result<()> {
        anyhow::ensure!(j0 <= j1 && j1 <= self.cols, "bad column range {j0}..{j1}");
        if j0 == j1 {
            return Ok(());
        }
        let (p0, p1) = (self.colptr[j0] as usize, self.colptr[j1] as usize);
        let nbytes = (p1 - p0) * ENTRY_BYTES;
        let mut staging = self.payload_scratch.lock().unwrap_or_else(|e| e.into_inner());
        staging.resize(nbytes, 0);
        self.file
            .read_exact_at(&mut staging[..], self.payload_offset() + (p0 * ENTRY_BYTES) as u64)
            .with_context(|| format!("reading sparse columns {j0}..{j1}"))?;
        let mut off = 0usize;
        for j in j0..j1 {
            let cn = (self.colptr[j + 1] - self.colptr[j]) as usize;
            // Validation pass over the row indices (8 of each entry's 16
            // bytes) before anything is pushed into `out`.
            let mut prev: Option<usize> = None;
            for t in 0..cn {
                let e = off + t * ENTRY_BYTES;
                let row = u64::from_le_bytes(staging[e..e + 8].try_into().unwrap()) as usize;
                anyhow::ensure!(
                    row < self.rows,
                    "corrupt sparse store: row {row} out of bounds in column {j}"
                );
                anyhow::ensure!(
                    prev.is_none_or(|p| p < row),
                    "corrupt sparse store: rows not strictly ascending in column {j}"
                );
                prev = Some(row);
            }
            let base = off;
            out.push_col_with(cn, |t| {
                let e = base + t * ENTRY_BYTES;
                let row = u64::from_le_bytes(staging[e..e + 8].try_into().unwrap()) as usize;
                let val = f64::from_le_bytes(staging[e + 8..e + 16].try_into().unwrap());
                (row, val)
            });
            off += cn * ENTRY_BYTES;
        }
        Ok(())
    }
}

/// Incremental sparse-store writer: columns are appended in order (a
/// generator can stream a matrix to disk without materializing it); the
/// column pointer and `nnz` are backfilled into their reserved regions
/// at [`SparseNmfStoreWriter::finish`].
pub struct SparseNmfStoreWriter {
    file: File,
    rows: usize,
    cols: usize,
    colptr: Vec<u64>,
    buf: Vec<u8>,
}

impl SparseNmfStoreWriter {
    pub fn create(
        path: &Path,
        rows: usize,
        cols: usize,
        block: usize,
    ) -> Result<SparseNmfStoreWriter> {
        anyhow::ensure!(rows > 0 && cols > 0 && block > 0, "degenerate sparse store shape");
        let mut file =
            File::create(path).with_context(|| format!("creating {}", path.display()))?;
        file.write_all(SPARSE_MAGIC)?;
        file.write_all(&(rows as u64).to_le_bytes())?;
        file.write_all(&(cols as u64).to_le_bytes())?;
        file.write_all(&(block as u64).to_le_bytes())?;
        file.write_all(&0u64.to_le_bytes())?; // nnz, backfilled at finish
        // Reserve the colptr region (backfilled at finish).
        file.write_all(&vec![0u8; (cols + 1) * 8])?;
        let mut colptr = Vec::with_capacity(cols + 1);
        colptr.push(0);
        Ok(SparseNmfStoreWriter { file, rows, cols, colptr, buf: Vec::new() })
    }

    /// Append the next column's `(row indices, values)` — rows strictly
    /// ascending and in bounds, values finite (the [`CscMat`] invariants,
    /// validated here so a corrupt file can never be produced).
    pub fn append_col(&mut self, rows: &[usize], vals: &[f64]) -> Result<()> {
        anyhow::ensure!(
            (self.colptr.len() - 1) < self.cols,
            "all {} columns already written",
            self.cols
        );
        anyhow::ensure!(rows.len() == vals.len(), "append_col: length mismatch");
        for (t, (&i, &v)) in rows.iter().zip(vals.iter()).enumerate() {
            anyhow::ensure!(i < self.rows, "append_col: row {i} out of bounds ({})", self.rows);
            anyhow::ensure!(t == 0 || rows[t - 1] < i, "append_col: rows must strictly ascend");
            anyhow::ensure!(v.is_finite(), "append_col: non-finite value {v}");
        }
        self.buf.clear();
        self.buf.reserve(rows.len() * ENTRY_BYTES);
        for (&i, &v) in rows.iter().zip(vals.iter()) {
            self.buf.extend_from_slice(&(i as u64).to_le_bytes());
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
        self.file.write_all(&self.buf)?;
        let prev = *self.colptr.last().unwrap();
        self.colptr.push(prev + rows.len() as u64);
        Ok(())
    }

    /// Finish: errors if the column count is short, then backfills `nnz`
    /// and the column pointer into their reserved regions.
    pub fn finish(mut self) -> Result<()> {
        anyhow::ensure!(
            self.colptr.len() == self.cols + 1,
            "sparse store incomplete: {}/{} columns written",
            self.colptr.len() - 1,
            self.cols
        );
        let nnz = *self.colptr.last().unwrap();
        self.file.write_all_at(&nnz.to_le_bytes(), 32).context("backfilling nnz")?;
        let mut ptr_bytes = Vec::with_capacity(self.colptr.len() * 8);
        for p in &self.colptr {
            ptr_bytes.extend_from_slice(&p.to_le_bytes());
        }
        self.file
            .write_all_at(&ptr_bytes, SPARSE_HEADER_BYTES)
            .context("backfilling column pointer")?;
        self.file.flush()?;
        Ok(())
    }
}

/// Write an in-memory CSC matrix as a sparse store (tests and small
/// data; the streaming [`SparseNmfStoreWriter`] is the production path).
pub fn write_csc(path: &Path, x: &CscMat, block: usize) -> Result<()> {
    let mut w = SparseNmfStoreWriter::create(path, x.rows(), x.cols(), block)?;
    for j in 0..x.cols() {
        let (is, vs) = x.col(j);
        w.append_col(is, vs)?;
    }
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Pcg64;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("randnmf_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_exact() {
        let mut rng = Pcg64::seed_from_u64(1);
        let m = rng.uniform_mat(17, 23);
        let path = tmp("roundtrip.nmfstore");
        write_mat(&path, &m, 5).unwrap();
        let store = NmfStore::open(&path).unwrap();
        assert_eq!(store.rows(), 17);
        assert_eq!(store.cols(), 23);
        assert_eq!(store.block_width(), 5);
        assert_eq!(store.read_all().unwrap(), m);
    }

    #[test]
    fn arbitrary_column_ranges() {
        let mut rng = Pcg64::seed_from_u64(2);
        let m = rng.uniform_mat(9, 31);
        let path = tmp("ranges.nmfstore");
        write_mat(&path, &m, 7).unwrap();
        let store = NmfStore::open(&path).unwrap();
        for (j0, j1) in [(0, 31), (0, 1), (30, 31), (3, 11), (6, 8), (7, 14), (13, 29)] {
            assert_eq!(store.read_cols(j0, j1).unwrap(), m.col_block(j0, j1), "{j0}..{j1}");
        }
        assert!(store.read_cols(5, 5).is_err());
        assert!(store.read_cols(0, 32).is_err());
    }

    #[test]
    fn streaming_writer_validates() {
        let path = tmp("stream.nmfstore");
        let mut w = NmfStoreWriter::create(&path, 4, 10, 4).unwrap();
        let mut rng = Pcg64::seed_from_u64(3);
        w.write_block(&rng.uniform_mat(4, 4)).unwrap();
        // wrong width rejected
        assert!(w.write_block(&rng.uniform_mat(4, 3)).is_err());
        w.write_block(&rng.uniform_mat(4, 4)).unwrap();
        // premature finish rejected
        let w2 = NmfStoreWriter::create(&tmp("short.nmfstore"), 2, 5, 2).unwrap();
        assert!(w2.finish().is_err());
        w.write_block(&rng.uniform_mat(4, 2)).unwrap(); // final short block
        w.finish().unwrap();
        assert_eq!(NmfStore::open(&path).unwrap().cols(), 10);
    }

    #[test]
    fn read_block_into_matches_read_cols_any_range() {
        let mut rng = Pcg64::seed_from_u64(7);
        let m = rng.uniform_mat(11, 29);
        let path = tmp("block_into.nmfstore");
        write_mat(&path, &m, 6).unwrap();
        let store = NmfStore::open(&path).unwrap();
        // One reusable buffer across aligned, straddling, and short ranges.
        let mut buf = crate::linalg::mat::Mat::zeros(1, 1);
        for (j0, j1) in [(0, 6), (6, 12), (24, 29), (0, 29), (4, 9), (5, 23), (28, 29)] {
            store.read_block_into(j0, j1, &mut buf).unwrap();
            assert_eq!(buf, m.col_block(j0, j1), "{j0}..{j1}");
        }
        assert!(store.read_block_into(3, 3, &mut buf).is_err());
        assert!(store.read_block_into(0, 30, &mut buf).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmp("bad.nmfstore");
        std::fs::write(&path, b"NOTASTORExxxxxxxxxxxxxxxxxxxxxxx").unwrap();
        assert!(NmfStore::open(&path).is_err());
    }

    fn sparse_fixture(m: usize, n: usize, seed: u64) -> (Mat, CscMat) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let dense = rng.uniform_mat(m, n).map(|v| if v < 0.7 { 0.0 } else { v });
        let csc = CscMat::from_csr(&crate::linalg::sparse::CsrMat::from_dense(&dense));
        (dense, csc)
    }

    #[test]
    fn sparse_store_roundtrip_exact() {
        let (_dense, csc) = sparse_fixture(17, 23, 10);
        let path = tmp("sparse_roundtrip.nmfstore");
        write_csc(&path, &csc, 5).unwrap();
        let store = SparseNmfStore::open(&path).unwrap();
        assert_eq!(store.rows(), 17);
        assert_eq!(store.cols(), 23);
        assert_eq!(store.block_width(), 5);
        assert_eq!(SparseColumnBlockSource::nnz(&store), csc.nnz());
        assert_eq!(store.read_all().unwrap(), csc);
    }

    #[test]
    fn sparse_store_arbitrary_column_ranges() {
        let (_dense, csc) = sparse_fixture(9, 31, 11);
        let path = tmp("sparse_ranges.nmfstore");
        write_csc(&path, &csc, 7).unwrap();
        let store = SparseNmfStore::open(&path).unwrap();
        let mut block = CscBlock::new();
        for (j0, j1) in [(0, 31), (0, 1), (30, 31), (3, 11), (6, 8), (13, 29)] {
            block.clear();
            store.read_block_into(j0, j1, &mut block).unwrap();
            assert_eq!(block.ncols(), j1 - j0, "{j0}..{j1}");
            for j in j0..j1 {
                let (is, vs) = block.col(j - j0);
                let (eis, evs) = csc.col(j);
                assert_eq!(is, eis, "col {j}: rows");
                assert_eq!(vs, evs, "col {j}: values");
            }
        }
        block.clear();
        assert!(store.read_block_into(0, 32, &mut block).is_err());
        // Empty range is a no-op append (the chunk assembler relies on
        // range semantics j0 <= j1).
        assert!(store.read_block_into(5, 5, &mut block).is_ok());
        assert_eq!(block.ncols(), 0);
    }

    #[test]
    fn sparse_store_writer_validates() {
        let path = tmp("sparse_stream.nmfstore");
        let mut w = SparseNmfStoreWriter::create(&path, 6, 3, 2).unwrap();
        w.append_col(&[0, 4], &[1.0, 2.0]).unwrap();
        // Unsorted / OOB / non-finite / ragged columns rejected.
        assert!(w.append_col(&[3, 1], &[1.0, 2.0]).is_err(), "descending rows");
        assert!(w.append_col(&[6], &[1.0]).is_err(), "row out of bounds");
        assert!(w.append_col(&[1], &[f64::NAN]).is_err(), "non-finite value");
        assert!(w.append_col(&[1, 2], &[1.0]).is_err(), "ragged column");
        w.append_col(&[], &[]).unwrap();
        // Premature finish rejected.
        let w2 = SparseNmfStoreWriter::create(&tmp("sparse_short.nmfstore"), 2, 5, 2).unwrap();
        assert!(w2.finish().is_err());
        w.append_col(&[5], &[3.0]).unwrap();
        assert!(w.append_col(&[0], &[1.0]).is_err(), "extra column rejected");
        w.finish().unwrap();
        let store = SparseNmfStore::open(&path).unwrap();
        assert_eq!(SparseColumnBlockSource::nnz(&store), 3);
        // Dense magic is rejected by the sparse opener and vice versa.
        let dense_path = tmp("dense_for_magic.nmfstore");
        write_mat(&dense_path, &Mat::full(2, 2, 1.0), 1).unwrap();
        assert!(SparseNmfStore::open(&dense_path).is_err());
        assert!(NmfStore::open(&path).is_err());
    }

    #[test]
    fn sparse_store_corrupt_payload_errors_not_panics() {
        // A file whose colptr is consistent but whose payload carries an
        // out-of-bounds row index must surface as Err at read time —
        // never as a panic inside a downstream kernel.
        let (_dense, csc) = sparse_fixture(8, 6, 14);
        assert!(csc.nnz() > 0);
        let path = tmp("sparse_corrupt.nmfstore");
        write_csc(&path, &csc, 3).unwrap();
        // Overwrite the first payload entry's row with rows + 7.
        let payload_off = 40 + (6 + 1) * 8;
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[payload_off..payload_off + 8].copy_from_slice(&15u64.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let store = SparseNmfStore::open(&path).unwrap();
        let mut block = CscBlock::new();
        let err = store.read_block_into(0, 6, &mut block);
        assert!(err.is_err(), "OOB payload row must be an Err");
        assert!(store.read_all().is_err());
    }

    #[test]
    fn out_of_core_sparse_qb_matches_in_memory_bitwise() {
        use crate::sketch::blocked::{qb_blocked_sparse, CscSource};
        use crate::sketch::qb::QbOptions;
        let (dense, csc) = sparse_fixture(40, 33, 12);
        let path = tmp("sparse_qb.nmfstore");
        write_csc(&path, &csc, 8).unwrap();
        let store = SparseNmfStore::open(&path).unwrap();
        let opts = QbOptions::new(5).with_oversample(6).with_power_iters(1);
        let mut r1 = Pcg64::seed_from_u64(13);
        let mut r2 = Pcg64::seed_from_u64(13);
        let from_disk = qb_blocked_sparse(&store, opts, 8, &mut r1).unwrap();
        let from_mem = qb_blocked_sparse(&CscSource(&csc), opts, 8, &mut r2).unwrap();
        assert_eq!(from_disk.q, from_mem.q, "disk and memory sources must bit-match");
        assert_eq!(from_disk.b, from_mem.b);
        assert!(from_disk.relative_error(&dense) < 1e-6);
    }

    #[test]
    fn out_of_core_qb_matches_in_memory() {
        use crate::sketch::blocked::{qb_blocked, MatSource};
        use crate::sketch::qb::QbOptions;
        let mut rng = Pcg64::seed_from_u64(4);
        let u = rng.uniform_mat(40, 5);
        let v = rng.uniform_mat(5, 33);
        let m = crate::linalg::gemm::matmul(&u, &v);
        let path = tmp("qb.nmfstore");
        write_mat(&path, &m, 8).unwrap();
        let store = NmfStore::open(&path).unwrap();
        let opts = QbOptions::new(5).with_oversample(6).with_power_iters(1);
        let mut r1 = Pcg64::seed_from_u64(5);
        let mut r2 = Pcg64::seed_from_u64(5);
        let from_disk = qb_blocked(&store, opts, 8, &mut r1).unwrap();
        let from_mem = qb_blocked(&MatSource(&m), opts, 8, &mut r2).unwrap();
        assert!(from_disk.q.max_abs_diff(&from_mem.q) < 1e-12);
        assert!(from_disk.b.max_abs_diff(&from_mem.b) < 1e-12);
        assert!(from_disk.relative_error(&m) < 1e-8);
    }
}

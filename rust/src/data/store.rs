//! `.nmfstore` — the column-blocked on-disk matrix store (dense and
//! sparse).
//!
//! The paper's out-of-core discussion (Appendix A) assumes an HDF5-style
//! container that can hand back subsets of columns without touching the
//! rest of the file. This is our substitute: a flat binary format whose
//! unit of I/O is a **column block**, so the blocked QB algorithm streams
//! `2 + 2q` sequential passes with `O(m·block)` memory.
//!
//! Dense layout (little-endian):
//!
//! ```text
//! magic    8 bytes  "NMFSTOR1"
//! rows     u64
//! cols     u64
//! block    u64                  column-block width
//! data     ⌈cols/block⌉ blocks, each a rows×bw row-major f64 slab
//! ```
//!
//! Sparse (CSC-slab) extension — [`SparseNmfStore`] — stores the matrix
//! column-major so any column range is one contiguous byte range and a
//! streaming pass costs `O(nnz)` I/O instead of `O(m·n)`:
//!
//! ```text
//! magic    8 bytes  "NMFSPRS1"
//! rows     u64
//! cols     u64
//! block    u64                  column-slab width (metadata)
//! nnz      u64                  total stored entries
//! colptr   (cols+1) × u64       absolute entry offset per column
//! payload  nnz entries, each {row u64, value f64} ascending-row per col
//! ```
//!
//! `colptr` is loaded at open (`O(cols)` resident — 8 MB per million
//! columns), after which reading columns `[j0, j1)` is exactly one
//! `pread` of `16·(colptr[j1] − colptr[j0])` bytes plus an in-place
//! decode into the caller's reusable
//! [`CscBlock`](crate::sketch::blocked::CscBlock) — zero steady-state
//! allocations, the contract [`qb_blocked_sparse_with`] relies on.
//!
//! [`qb_blocked_sparse_with`]: crate::sketch::blocked::qb_blocked_sparse_with
//!
//! Reads use `pread` via [`robust::pread_exact`] — short reads and
//! `EINTR` are absorbed, transient failures retried with bounded backoff
//! ([`robust::with_retry`]), and every failure carries the
//! `Corrupt`/`Transient`/`Fatal` taxonomy of [`crate::data::robust`] — so
//! a shared store handle can serve concurrent readers without seek races
//! and a flaky filesystem degrades to typed errors, never panics.
//!
//! ## Checksums
//!
//! Both formats gain a backward-compatible **CRC footer** (tag
//! `"NMFCRCF1"` appended after the payload): the dense footer carries the
//! header CRC plus one CRC32 *per column-block slab*, validated on every
//! slab read; the sparse footer carries header, column-pointer, and
//! payload CRCs — header and colptr are validated at open, the payload by
//! [`SparseNmfStore::verify_integrity`] (reads there are arbitrary column
//! ranges, so whole-payload validation is an explicit scrub rather than a
//! per-read tax). Every writer emits the footer; footer-less files from
//! older writers still open and read (with a file-length sanity check but
//! no checksum protection).

use std::fs::File;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::data::robust;
use crate::linalg::mat::Mat;
use crate::linalg::sparse::CscMat;
use crate::sketch::blocked::{ColumnBlockSource, CscBlock, SparseColumnBlockSource};

const MAGIC: &[u8; 8] = b"NMFSTOR1";
const SPARSE_MAGIC: &[u8; 8] = b"NMFSPRS1";
/// Tag opening the optional CRC footer of both store formats.
const FOOTER_MAGIC: &[u8; 8] = b"NMFCRCF1";

/// Read handle for a `.nmfstore` file.
pub struct NmfStore {
    file: File,
    rows: usize,
    cols: usize,
    block: usize,
    /// Reusable slab staging for `read_block_into`'s misaligned path:
    /// grown once to the native slab size, then reused, so the
    /// out-of-core reader performs one `pread` per slab and zero
    /// steady-state allocations. Behind a mutex because reads take
    /// `&self`; only the blocked-QB driver (single-threaded) uses it, so
    /// contention is nil and `read_cols`' concurrent readers are
    /// unaffected (they allocate their own slabs as before).
    slab_scratch: Mutex<Vec<f64>>,
    /// Per-slab CRC32s from the footer; `None` for legacy footer-less
    /// files (which read without checksum protection).
    block_crcs: Option<Vec<u32>>,
}

impl NmfStore {
    /// Open an existing store.
    ///
    /// The header is read through the hardened positional-read path, the
    /// file length is checked against the header's geometry (a truncated
    /// store fails here, not mid-pass), and when the CRC footer is
    /// present its header checksum is validated and the per-slab
    /// checksums are loaded for use on every subsequent read.
    pub fn open(path: &Path) -> Result<NmfStore> {
        let file = File::open(path)
            .map_err(|e| robust::io_fault(&format!("opening {}", path.display()), e))?;
        let mut header = [0u8; 32];
        robust::with_retry("read store header", || {
            robust::pread_exact(&file, &mut header, 0)
                .map_err(|e| robust::io_fault("reading header", e))
        })?;
        if &header[0..8] != MAGIC {
            bail!("{}", robust::corrupt(format!("{} is not an nmfstore file", path.display())));
        }
        let rows = u64::from_le_bytes(header[8..16].try_into().unwrap()) as usize;
        let cols = u64::from_le_bytes(header[16..24].try_into().unwrap()) as usize;
        let block = u64::from_le_bytes(header[24..32].try_into().unwrap()) as usize;
        if block == 0 || rows == 0 || cols == 0 {
            bail!(
                "{}",
                robust::corrupt(format!("degenerate store dimensions {rows}x{cols} block {block}"))
            );
        }
        let data_bytes = (rows as u64)
            .checked_mul(cols as u64)
            .and_then(|c| c.checked_mul(8))
            .ok_or_else(|| robust::corrupt(format!("implausible store dims {rows}x{cols}")))?;
        let len = file.metadata().map_err(|e| robust::io_fault("stat store file", e))?.len();
        let plain_len = 32 + data_bytes;
        let nblocks = cols.div_ceil(block);
        let footer_len = (8 + 4 + 4 * nblocks) as u64;
        let block_crcs = if len == plain_len {
            None // legacy footer-less file
        } else if Some(len) == plain_len.checked_add(footer_len) {
            let mut footer = vec![0u8; footer_len as usize];
            robust::with_retry("read store footer", || {
                robust::pread_exact(&file, &mut footer, plain_len)
                    .map_err(|e| robust::io_fault("reading CRC footer", e))
            })?;
            anyhow::ensure!(
                &footer[0..8] == FOOTER_MAGIC,
                "{}",
                robust::corrupt("store CRC footer has a bad tag")
            );
            let stored = u32::from_le_bytes(footer[8..12].try_into().unwrap());
            let got = robust::crc32(&header);
            anyhow::ensure!(
                got == stored,
                "{}",
                robust::corrupt(format!(
                    "store header CRC mismatch: stored {stored:#010x}, computed {got:#010x}"
                ))
            );
            let crcs: Vec<u32> = footer[12..]
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Some(crcs)
        } else {
            bail!(
                "{}",
                robust::corrupt(format!(
                    "store length {len} matches neither the bare layout ({plain_len} bytes) \
                     nor the checksummed one ({} bytes): truncated or trailing garbage",
                    plain_len + footer_len
                ))
            );
        };
        Ok(NmfStore { file, rows, cols, block, slab_scratch: Mutex::new(Vec::new()), block_crcs })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Native block width (reads at this granularity are single-slab).
    pub fn block_width(&self) -> usize {
        self.block
    }

    /// Byte offset of block `bi` (blocks before it are all full except
    /// possibly none — only the last block is short).
    fn block_offset(&self, bi: usize) -> u64 {
        32 + (bi * self.block * self.rows * 8) as u64
    }

    fn block_cols_of(&self, bi: usize) -> usize {
        let j0 = bi * self.block;
        (self.cols - j0).min(self.block)
    }

    /// `pread` slab `bi` into `buf` (its exact byte size): short reads
    /// and `EINTR` absorbed, transient faults retried with backoff, and
    /// the slab CRC validated when the store carries a footer — a flipped
    /// bit in flight heals on the corrupt-retry, on-disk rot becomes a
    /// typed `Corrupt` error. Zero allocations on the success path.
    fn pread_block(&self, bi: usize, buf: &mut [u8]) -> Result<()> {
        robust::with_retry("read store block", || {
            robust::pread_exact(&self.file, buf, self.block_offset(bi))
                .map_err(|e| robust::io_fault(&format!("reading block {bi}"), e))?;
            if let Some(crcs) = &self.block_crcs {
                let got = robust::crc32(buf);
                anyhow::ensure!(
                    got == crcs[bi],
                    "{}",
                    robust::corrupt(format!(
                        "block {bi} CRC mismatch: stored {:#010x}, computed {got:#010x}",
                        crcs[bi]
                    ))
                );
            }
            Ok(())
        })
    }

    /// Read one whole native block as a rows×bw matrix.
    pub fn read_native_block(&self, bi: usize) -> Result<Mat> {
        let bw = self.block_cols_of(bi);
        anyhow::ensure!(bw > 0, "block index {bi} out of range");
        let nbytes = self.rows * bw * 8;
        let mut buf = vec![0u8; nbytes];
        self.pread_block(bi, &mut buf)?;
        let data: Vec<f64> = buf
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Mat::from_vec(self.rows, bw, data))
    }

    /// Scrub the whole store: re-read every slab, validating the per-slab
    /// CRCs when the footer is present. `Ok(())` means every byte is
    /// readable and checksum-clean; legacy footer-less files get the
    /// readability check only.
    pub fn verify_integrity(&self) -> Result<()> {
        let mut scratch = self.slab_scratch.lock().unwrap_or_else(|e| e.into_inner());
        for bi in 0..self.cols.div_ceil(self.block) {
            let bw = self.block_cols_of(bi);
            scratch.resize(self.rows * bw, 0.0);
            self.pread_block(bi, as_bytes_mut(&mut scratch[..]))?;
        }
        Ok(())
    }

    /// Read an arbitrary column range `[j0, j1)` (slices native blocks).
    pub fn read_cols(&self, j0: usize, j1: usize) -> Result<Mat> {
        anyhow::ensure!(j0 < j1 && j1 <= self.cols, "bad column range {j0}..{j1}");
        let mut out = Mat::zeros(self.rows, j1 - j0);
        let mut bi = j0 / self.block;
        loop {
            let b0 = bi * self.block;
            if b0 >= j1 {
                break;
            }
            let blk = self.read_native_block(bi)?;
            let lo = j0.max(b0);
            let hi = j1.min(b0 + blk.cols());
            let piece = blk.col_block(lo - b0, hi - b0);
            out.set_col_block(lo - j0, &piece);
            bi += 1;
        }
        Ok(out)
    }

    /// Materialize the full matrix (small stores / tests only).
    pub fn read_all(&self) -> Result<Mat> {
        self.read_cols(0, self.cols)
    }
}

/// View an `f64` slice as raw little-endian-file bytes for `pread`ing
/// straight into matrix storage (no staging buffer, no allocation).
fn as_bytes_mut(s: &mut [f64]) -> &mut [u8] {
    // SAFETY: f64 and [u8; 8] have no invalid bit patterns; the slice
    // covers exactly the same memory. Callers fix endianness afterwards.
    unsafe { std::slice::from_raw_parts_mut(s.as_mut_ptr() as *mut u8, s.len() * 8) }
}

/// Reinterpret bytes just `pread` into `s` as little-endian `f64`s, in
/// place (no-op on little-endian hosts).
fn fix_le_in_place(s: &mut [f64]) {
    for v in s {
        *v = f64::from_bits(u64::from_le((*v).to_bits()));
    }
}

impl ColumnBlockSource for NmfStore {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    fn read_block(&self, j0: usize, j1: usize) -> Result<Mat> {
        self.read_cols(j0, j1)
    }

    /// Allocation-free block read: a block-aligned range is `pread`
    /// directly into `out`'s storage; a misaligned range reads each
    /// overlapped slab whole into the store's reusable staging buffer and
    /// copies the needed column segments out. Either way: one contiguous
    /// read per slab, endian-fix in place, zero steady-state allocations
    /// once the buffers are warm — what the out-of-core QB path relies on.
    fn read_block_into(&self, j0: usize, j1: usize, out: &mut Mat) -> Result<()> {
        anyhow::ensure!(j0 < j1 && j1 <= self.cols, "bad column range {j0}..{j1}");
        let w = j1 - j0;
        out.resize(self.rows, w);
        // Fast path: the range is exactly one whole native block — the
        // on-disk slab layout matches `out` row-major, one contiguous read.
        if j0 % self.block == 0 && self.block_cols_of(j0 / self.block) == w {
            let bi = j0 / self.block;
            self.pread_block(bi, as_bytes_mut(out.as_mut_slice()))?;
            fix_le_in_place(out.as_mut_slice());
            return Ok(());
        }
        // General path: one whole-slab `pread` per overlapped native
        // block into the reusable staging buffer, then copy the needed
        // column segments out row by row.
        let mut scratch = self.slab_scratch.lock().unwrap_or_else(|e| e.into_inner());
        let mut bi = j0 / self.block;
        loop {
            let b0 = bi * self.block;
            if b0 >= j1 {
                break;
            }
            let bw = self.block_cols_of(bi);
            let lo = j0.max(b0);
            let hi = j1.min(b0 + bw);
            scratch.resize(self.rows * bw, 0.0);
            self.pread_block(bi, as_bytes_mut(&mut scratch[..]))?;
            fix_le_in_place(&mut scratch[..]);
            for i in 0..self.rows {
                let src = &scratch[i * bw + (lo - b0)..i * bw + (hi - b0)];
                out.row_mut(i)[lo - j0..hi - j0].copy_from_slice(src);
            }
            bi += 1;
        }
        Ok(())
    }
}

/// Incremental writer: blocks are appended in order, so a generator can
/// stream a matrix to disk without materializing it.
///
/// Writes are positional ([`robust::pwrite_all`] at tracked offsets)
/// under the bounded retry policy — a transiently-failed write retries
/// idempotently — and [`NmfStoreWriter::finish`] appends the CRC footer
/// and `fsync`s, so a finished store is durable and self-validating.
pub struct NmfStoreWriter {
    file: File,
    rows: usize,
    cols: usize,
    block: usize,
    written_cols: usize,
    header_crc: u32,
    block_crcs: Vec<u32>,
}

impl NmfStoreWriter {
    pub fn create(path: &Path, rows: usize, cols: usize, block: usize) -> Result<NmfStoreWriter> {
        anyhow::ensure!(rows > 0 && cols > 0 && block > 0, "degenerate store shape");
        let file = File::create(path).with_context(|| format!("creating {}", path.display()))?;
        let mut header = [0u8; 32];
        header[0..8].copy_from_slice(MAGIC);
        header[8..16].copy_from_slice(&(rows as u64).to_le_bytes());
        header[16..24].copy_from_slice(&(cols as u64).to_le_bytes());
        header[24..32].copy_from_slice(&(block as u64).to_le_bytes());
        robust::with_retry("write store header", || {
            robust::pwrite_all(&file, &header, 0)
                .map_err(|e| robust::io_fault("writing header", e))
        })?;
        let header_crc = robust::crc32(&header);
        Ok(NmfStoreWriter {
            file,
            rows,
            cols,
            block,
            written_cols: 0,
            header_crc,
            block_crcs: Vec::new(),
        })
    }

    /// Append the next column block. Must be `block` wide except the last.
    pub fn write_block(&mut self, m: &Mat) -> Result<()> {
        anyhow::ensure!(m.rows() == self.rows, "row mismatch");
        let expected = (self.cols - self.written_cols).min(self.block);
        anyhow::ensure!(
            m.cols() == expected,
            "block width {} != expected {expected}",
            m.cols()
        );
        let mut buf = Vec::with_capacity(m.len() * 8);
        for &v in m.as_slice() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        let offset = 32 + (self.written_cols * self.rows * 8) as u64;
        robust::with_retry("write store block", || {
            robust::pwrite_all(&self.file, &buf, offset)
                .map_err(|e| robust::io_fault("writing block", e))
        })?;
        self.block_crcs.push(robust::crc32(&buf));
        self.written_cols += m.cols();
        Ok(())
    }

    /// Finish: errors if the column count is short, then appends the CRC
    /// footer and syncs the file to disk.
    pub fn finish(self) -> Result<()> {
        anyhow::ensure!(
            self.written_cols == self.cols,
            "store incomplete: {}/{} columns written",
            self.written_cols,
            self.cols
        );
        let mut footer = Vec::with_capacity(12 + 4 * self.block_crcs.len());
        footer.extend_from_slice(FOOTER_MAGIC);
        footer.extend_from_slice(&self.header_crc.to_le_bytes());
        for c in &self.block_crcs {
            footer.extend_from_slice(&c.to_le_bytes());
        }
        let offset = 32 + (self.cols * self.rows * 8) as u64;
        robust::with_retry("write store footer", || {
            robust::pwrite_all(&self.file, &footer, offset)
                .map_err(|e| robust::io_fault("writing CRC footer", e))
        })?;
        self.file.sync_all().map_err(|e| robust::io_fault("syncing store", e))?;
        Ok(())
    }
}

/// Write an in-memory matrix as a store (tests and small data).
pub fn write_mat(path: &Path, m: &Mat, block: usize) -> Result<()> {
    let mut w = NmfStoreWriter::create(path, m.rows(), m.cols(), block)?;
    let mut j0 = 0;
    while j0 < m.cols() {
        let j1 = (j0 + block).min(m.cols());
        w.write_block(&m.col_block(j0, j1))?;
        j0 = j1;
    }
    w.finish()
}

// ---------------------------------------------------------------------------
// Sparse (CSC-slab) store.
// ---------------------------------------------------------------------------

/// Byte offset of the sparse header (fixed) and derived regions.
const SPARSE_HEADER_BYTES: u64 = 40;
/// Bytes per payload entry: row `u64` + value `f64`.
const ENTRY_BYTES: usize = 16;

/// Read handle for a sparse (CSC-slab) `.nmfstore` file — see the module
/// docs for the layout. Implements
/// [`SparseColumnBlockSource`], so [`crate::sketch::blocked`]'s sparse
/// out-of-core engine streams it directly.
pub struct SparseNmfStore {
    file: File,
    rows: usize,
    cols: usize,
    block: usize,
    nnz: usize,
    /// Absolute per-column entry offsets (`cols + 1` values), loaded at
    /// open — what turns any column-range read into one contiguous
    /// `pread`.
    colptr: Vec<u64>,
    /// Reusable payload staging for `read_block_into` (same pattern as
    /// the dense store's `slab_scratch`): grown to the largest read once,
    /// then reused — one `pread` per range, zero steady-state
    /// allocations. Behind a mutex because reads take `&self`.
    payload_scratch: Mutex<Vec<u8>>,
    /// Whole-payload CRC32 from the footer, validated by
    /// [`SparseNmfStore::verify_integrity`]; `None` for legacy files.
    payload_crc: Option<u32>,
}

impl SparseNmfStore {
    /// Open an existing sparse store and load its column pointer.
    ///
    /// Header and column pointer are read through the hardened
    /// positional-read path, the file length is checked against the
    /// header's geometry (the column-pointer allocation is bounded by the
    /// actual file size, so a corrupt `cols` can never trigger a huge
    /// allocation), and when the CRC footer is present the header and
    /// column-pointer checksums are validated here; the payload checksum
    /// is kept for [`SparseNmfStore::verify_integrity`].
    pub fn open(path: &Path) -> Result<SparseNmfStore> {
        let file = File::open(path)
            .map_err(|e| robust::io_fault(&format!("opening {}", path.display()), e))?;
        let mut header = [0u8; SPARSE_HEADER_BYTES as usize];
        robust::with_retry("read sparse store header", || {
            robust::pread_exact(&file, &mut header, 0)
                .map_err(|e| robust::io_fault("reading sparse header", e))
        })?;
        if &header[0..8] != SPARSE_MAGIC {
            bail!(
                "{}",
                robust::corrupt(format!("{} is not a sparse nmfstore file", path.display()))
            );
        }
        let rows = u64::from_le_bytes(header[8..16].try_into().unwrap()) as usize;
        let cols = u64::from_le_bytes(header[16..24].try_into().unwrap()) as usize;
        let block = u64::from_le_bytes(header[24..32].try_into().unwrap()) as usize;
        let nnz = u64::from_le_bytes(header[32..40].try_into().unwrap()) as usize;
        if block == 0 || rows == 0 || cols == 0 {
            bail!(
                "{}",
                robust::corrupt(format!(
                    "degenerate sparse store dimensions {rows}x{cols} block {block}"
                ))
            );
        }
        let len = file.metadata().map_err(|e| robust::io_fault("stat sparse store", e))?.len();
        let ptr_bytes_len = (cols as u64)
            .checked_add(1)
            .and_then(|c| c.checked_mul(8))
            .filter(|&b| SPARSE_HEADER_BYTES + b <= len)
            .ok_or_else(|| {
                robust::corrupt(format!("column pointer for {cols} columns does not fit the file"))
            })?;
        let mut ptr_bytes = vec![0u8; ptr_bytes_len as usize];
        robust::with_retry("read sparse column pointer", || {
            robust::pread_exact(&file, &mut ptr_bytes, SPARSE_HEADER_BYTES)
                .map_err(|e| robust::io_fault("reading column pointer", e))
        })?;
        let colptr: Vec<u64> = ptr_bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        if colptr[0] != 0 || colptr[cols] as usize != nnz || colptr.windows(2).any(|w| w[0] > w[1])
        {
            bail!("{}", robust::corrupt(format!("corrupt column pointer in {}", path.display())));
        }
        let plain_len = (nnz as u64)
            .checked_mul(ENTRY_BYTES as u64)
            .and_then(|p| p.checked_add(SPARSE_HEADER_BYTES + ptr_bytes_len))
            .ok_or_else(|| robust::corrupt(format!("implausible sparse store nnz {nnz}")))?;
        let payload_crc = if len == plain_len {
            None // legacy footer-less file
        } else if Some(len) == plain_len.checked_add(20) {
            let mut footer = [0u8; 20];
            robust::with_retry("read sparse store footer", || {
                robust::pread_exact(&file, &mut footer, plain_len)
                    .map_err(|e| robust::io_fault("reading CRC footer", e))
            })?;
            anyhow::ensure!(
                &footer[0..8] == FOOTER_MAGIC,
                "{}",
                robust::corrupt("sparse store CRC footer has a bad tag")
            );
            let header_crc = u32::from_le_bytes(footer[8..12].try_into().unwrap());
            let colptr_crc = u32::from_le_bytes(footer[12..16].try_into().unwrap());
            let payload_crc = u32::from_le_bytes(footer[16..20].try_into().unwrap());
            for (what, stored, got) in [
                ("header", header_crc, robust::crc32(&header)),
                ("column pointer", colptr_crc, robust::crc32(&ptr_bytes)),
            ] {
                anyhow::ensure!(
                    got == stored,
                    "{}",
                    robust::corrupt(format!(
                        "sparse store {what} CRC mismatch: stored {stored:#010x}, \
                         computed {got:#010x}"
                    ))
                );
            }
            Some(payload_crc)
        } else {
            bail!(
                "{}",
                robust::corrupt(format!(
                    "sparse store length {len} matches neither the bare layout ({plain_len} \
                     bytes) nor the checksummed one ({} bytes): truncated or trailing garbage",
                    plain_len + 20
                ))
            );
        };
        Ok(SparseNmfStore {
            file,
            rows,
            cols,
            block,
            nnz,
            colptr,
            payload_scratch: Mutex::new(Vec::new()),
            payload_crc,
        })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Total stored entries.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Column-slab width metadata (reads are contiguous at any width; the
    /// value records the writer's streaming granularity for diagnostics).
    pub fn block_width(&self) -> usize {
        self.block
    }

    /// Byte offset where the entry payload begins.
    fn payload_offset(&self) -> u64 {
        SPARSE_HEADER_BYTES + ((self.cols + 1) * 8) as u64
    }

    /// Materialize the full matrix as a [`CscMat`] (small stores /
    /// tests): one streamed decode, assembled column-by-column in the
    /// order the block already holds, validated by
    /// [`CscMat::from_parts`] — a corrupt file is an `Err`, not a panic.
    pub fn read_all(&self) -> Result<CscMat> {
        let mut block = CscBlock::new();
        SparseColumnBlockSource::read_block_into(self, 0, self.cols, &mut block)?;
        let mut indptr = Vec::with_capacity(self.cols + 1);
        indptr.push(0);
        let mut indices = Vec::with_capacity(self.nnz);
        let mut values = Vec::with_capacity(self.nnz);
        for j in 0..self.cols {
            let (is, vs) = block.col(j);
            indices.extend_from_slice(is);
            values.extend_from_slice(vs);
            indptr.push(indices.len());
        }
        CscMat::from_parts(self.rows, self.cols, indptr, indices, values)
    }

    /// Scrub the payload: stream every entry byte back through the
    /// hardened read path and compare the whole-payload CRC32 from the
    /// footer. Header and column pointer were already validated at open.
    /// `Ok(())` means the file is readable end to end and checksum-clean;
    /// legacy footer-less files get the readability check only.
    pub fn verify_integrity(&self) -> Result<()> {
        const CHUNK: usize = 1 << 20;
        let total = self.nnz * ENTRY_BYTES;
        let mut staging = self.payload_scratch.lock().unwrap_or_else(|e| e.into_inner());
        let mut crc = 0u32;
        let mut done = 0usize;
        while done < total {
            let n = CHUNK.min(total - done);
            staging.resize(n, 0);
            let offset = self.payload_offset() + done as u64;
            robust::with_retry("scrub sparse payload", || {
                robust::pread_exact(&self.file, &mut staging[..n], offset)
                    .map_err(|e| robust::io_fault("scrubbing sparse payload", e))
            })?;
            crc = robust::crc32_update(crc, &staging[..n]);
            done += n;
        }
        if let Some(stored) = self.payload_crc {
            anyhow::ensure!(
                crc == stored,
                "{}",
                robust::corrupt(format!(
                    "sparse store payload CRC mismatch: stored {stored:#010x}, computed {crc:#010x}"
                ))
            );
        }
        Ok(())
    }
}

impl SparseColumnBlockSource for SparseNmfStore {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    fn nnz(&self) -> usize {
        self.nnz
    }

    /// Append columns `[j0, j1)` to `out`: exactly one `pread` of the
    /// contiguous entry range (CSC's gift — no slab alignment cases),
    /// then an in-place decode. Zero steady-state allocations once the
    /// staging buffer and `out` are warm.
    ///
    /// The payload is **validated as it is decoded** — row indices must
    /// be in bounds and strictly ascending per column (the invariants
    /// every downstream kernel indexes by) — so a corrupt or truncated
    /// file surfaces as an `Err` here instead of a panic (or a silent
    /// determinism break) deep inside a compute pass. The `open`-time
    /// check covers only the column pointer; this covers the entries.
    fn read_block_into(&self, j0: usize, j1: usize, out: &mut CscBlock) -> Result<()> {
        anyhow::ensure!(j0 <= j1 && j1 <= self.cols, "bad column range {j0}..{j1}");
        if j0 == j1 {
            return Ok(());
        }
        let (p0, p1) = (self.colptr[j0] as usize, self.colptr[j1] as usize);
        let nbytes = (p1 - p0) * ENTRY_BYTES;
        let mut staging = self.payload_scratch.lock().unwrap_or_else(|e| e.into_inner());
        staging.resize(nbytes, 0);
        let offset = self.payload_offset() + (p0 * ENTRY_BYTES) as u64;
        // Read *and validate* under the retry policy, before anything is
        // pushed into `out` — an in-flight bit flip in a row index is
        // caught by the validation pass and heals on the corrupt-retry;
        // only a fully validated buffer is ever decoded.
        robust::with_retry("read sparse store columns", || {
            robust::pread_exact(&self.file, &mut staging[..], offset)
                .map_err(|e| robust::io_fault(&format!("reading sparse columns {j0}..{j1}"), e))?;
            let mut off = 0usize;
            for j in j0..j1 {
                let cn = (self.colptr[j + 1] - self.colptr[j]) as usize;
                let mut prev: Option<usize> = None;
                for t in 0..cn {
                    let e = off + t * ENTRY_BYTES;
                    let row = u64::from_le_bytes(staging[e..e + 8].try_into().unwrap()) as usize;
                    anyhow::ensure!(
                        row < self.rows,
                        "{}",
                        robust::corrupt(format!(
                            "sparse store row {row} out of bounds in column {j}"
                        ))
                    );
                    anyhow::ensure!(
                        prev.is_none_or(|p| p < row),
                        "{}",
                        robust::corrupt(format!(
                            "sparse store rows not strictly ascending in column {j}"
                        ))
                    );
                    prev = Some(row);
                }
                off += cn * ENTRY_BYTES;
            }
            Ok(())
        })?;
        let mut off = 0usize;
        for j in j0..j1 {
            let cn = (self.colptr[j + 1] - self.colptr[j]) as usize;
            let base = off;
            out.push_col_with(cn, |t| {
                let e = base + t * ENTRY_BYTES;
                let row = u64::from_le_bytes(staging[e..e + 8].try_into().unwrap()) as usize;
                let val = f64::from_le_bytes(staging[e + 8..e + 16].try_into().unwrap());
                (row, val)
            });
            off += cn * ENTRY_BYTES;
        }
        Ok(())
    }
}

/// Incremental sparse-store writer: columns are appended in order (a
/// generator can stream a matrix to disk without materializing it); the
/// column pointer and `nnz` are backfilled into their reserved regions
/// at [`SparseNmfStoreWriter::finish`].
pub struct SparseNmfStoreWriter {
    file: File,
    rows: usize,
    cols: usize,
    block: usize,
    colptr: Vec<u64>,
    buf: Vec<u8>,
    payload_crc: u32,
}

impl SparseNmfStoreWriter {
    pub fn create(
        path: &Path,
        rows: usize,
        cols: usize,
        block: usize,
    ) -> Result<SparseNmfStoreWriter> {
        anyhow::ensure!(rows > 0 && cols > 0 && block > 0, "degenerate sparse store shape");
        let file = File::create(path).with_context(|| format!("creating {}", path.display()))?;
        // Provisional header (nnz 0, rewritten whole at finish) plus the
        // zeroed colptr reservation, written positionally so a transient
        // failure retries idempotently.
        let mut lead = vec![0u8; SPARSE_HEADER_BYTES as usize + (cols + 1) * 8];
        lead[0..8].copy_from_slice(SPARSE_MAGIC);
        lead[8..16].copy_from_slice(&(rows as u64).to_le_bytes());
        lead[16..24].copy_from_slice(&(cols as u64).to_le_bytes());
        lead[24..32].copy_from_slice(&(block as u64).to_le_bytes());
        robust::with_retry("write sparse store header", || {
            robust::pwrite_all(&file, &lead, 0)
                .map_err(|e| robust::io_fault("writing sparse header", e))
        })?;
        let mut colptr = Vec::with_capacity(cols + 1);
        colptr.push(0);
        let buf = Vec::new();
        Ok(SparseNmfStoreWriter { file, rows, cols, block, colptr, buf, payload_crc: 0 })
    }

    /// Append the next column's `(row indices, values)` — rows strictly
    /// ascending and in bounds, values finite (the [`CscMat`] invariants,
    /// validated here so a corrupt file can never be produced).
    pub fn append_col(&mut self, rows: &[usize], vals: &[f64]) -> Result<()> {
        anyhow::ensure!(
            (self.colptr.len() - 1) < self.cols,
            "all {} columns already written",
            self.cols
        );
        anyhow::ensure!(rows.len() == vals.len(), "append_col: length mismatch");
        for (t, (&i, &v)) in rows.iter().zip(vals.iter()).enumerate() {
            anyhow::ensure!(i < self.rows, "append_col: row {i} out of bounds ({})", self.rows);
            anyhow::ensure!(t == 0 || rows[t - 1] < i, "append_col: rows must strictly ascend");
            anyhow::ensure!(v.is_finite(), "append_col: non-finite value {v}");
        }
        self.buf.clear();
        self.buf.reserve(rows.len() * ENTRY_BYTES);
        for (&i, &v) in rows.iter().zip(vals.iter()) {
            self.buf.extend_from_slice(&(i as u64).to_le_bytes());
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
        let prev = *self.colptr.last().unwrap();
        let offset = SPARSE_HEADER_BYTES + ((self.cols + 1) * 8) as u64 + prev * ENTRY_BYTES as u64;
        robust::with_retry("append sparse column", || {
            robust::pwrite_all(&self.file, &self.buf, offset)
                .map_err(|e| robust::io_fault("appending sparse column", e))
        })?;
        self.payload_crc = robust::crc32_update(self.payload_crc, &self.buf);
        self.colptr.push(prev + rows.len() as u64);
        Ok(())
    }

    /// Finish: errors if the column count is short, then backfills `nnz`
    /// and the column pointer into their reserved regions, appends the
    /// CRC footer (header, column-pointer, and payload checksums), and
    /// syncs the file to disk.
    pub fn finish(self) -> Result<()> {
        anyhow::ensure!(
            self.colptr.len() == self.cols + 1,
            "sparse store incomplete: {}/{} columns written",
            self.colptr.len() - 1,
            self.cols
        );
        let nnz = *self.colptr.last().unwrap();
        let mut header = [0u8; SPARSE_HEADER_BYTES as usize];
        header[0..8].copy_from_slice(SPARSE_MAGIC);
        header[8..16].copy_from_slice(&(self.rows as u64).to_le_bytes());
        header[16..24].copy_from_slice(&(self.cols as u64).to_le_bytes());
        header[24..32].copy_from_slice(&(self.block as u64).to_le_bytes());
        header[32..40].copy_from_slice(&nnz.to_le_bytes());
        let mut ptr_bytes = Vec::with_capacity(self.colptr.len() * 8);
        for p in &self.colptr {
            ptr_bytes.extend_from_slice(&p.to_le_bytes());
        }
        let mut footer = Vec::with_capacity(20);
        footer.extend_from_slice(FOOTER_MAGIC);
        footer.extend_from_slice(&robust::crc32(&header).to_le_bytes());
        footer.extend_from_slice(&robust::crc32(&ptr_bytes).to_le_bytes());
        footer.extend_from_slice(&self.payload_crc.to_le_bytes());
        let footer_off = SPARSE_HEADER_BYTES + ptr_bytes.len() as u64 + nnz * ENTRY_BYTES as u64;
        robust::with_retry("finalize sparse store", || {
            robust::pwrite_all(&self.file, &header, 0)
                .map_err(|e| robust::io_fault("backfilling sparse header", e))?;
            robust::pwrite_all(&self.file, &ptr_bytes, SPARSE_HEADER_BYTES)
                .map_err(|e| robust::io_fault("backfilling column pointer", e))?;
            robust::pwrite_all(&self.file, &footer, footer_off)
                .map_err(|e| robust::io_fault("writing CRC footer", e))?;
            Ok(())
        })?;
        self.file.sync_all().map_err(|e| robust::io_fault("syncing sparse store", e))?;
        Ok(())
    }
}

/// Write an in-memory CSC matrix as a sparse store (tests and small
/// data; the streaming [`SparseNmfStoreWriter`] is the production path).
pub fn write_csc(path: &Path, x: &CscMat, block: usize) -> Result<()> {
    let mut w = SparseNmfStoreWriter::create(path, x.rows(), x.cols(), block)?;
    for j in 0..x.cols() {
        let (is, vs) = x.col(j);
        w.append_col(is, vs)?;
    }
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Pcg64;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("randnmf_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_exact() {
        let mut rng = Pcg64::seed_from_u64(1);
        let m = rng.uniform_mat(17, 23);
        let path = tmp("roundtrip.nmfstore");
        write_mat(&path, &m, 5).unwrap();
        let store = NmfStore::open(&path).unwrap();
        assert_eq!(store.rows(), 17);
        assert_eq!(store.cols(), 23);
        assert_eq!(store.block_width(), 5);
        assert_eq!(store.read_all().unwrap(), m);
    }

    #[test]
    fn arbitrary_column_ranges() {
        let mut rng = Pcg64::seed_from_u64(2);
        let m = rng.uniform_mat(9, 31);
        let path = tmp("ranges.nmfstore");
        write_mat(&path, &m, 7).unwrap();
        let store = NmfStore::open(&path).unwrap();
        for (j0, j1) in [(0, 31), (0, 1), (30, 31), (3, 11), (6, 8), (7, 14), (13, 29)] {
            assert_eq!(store.read_cols(j0, j1).unwrap(), m.col_block(j0, j1), "{j0}..{j1}");
        }
        assert!(store.read_cols(5, 5).is_err());
        assert!(store.read_cols(0, 32).is_err());
    }

    #[test]
    fn streaming_writer_validates() {
        let path = tmp("stream.nmfstore");
        let mut w = NmfStoreWriter::create(&path, 4, 10, 4).unwrap();
        let mut rng = Pcg64::seed_from_u64(3);
        w.write_block(&rng.uniform_mat(4, 4)).unwrap();
        // wrong width rejected
        assert!(w.write_block(&rng.uniform_mat(4, 3)).is_err());
        w.write_block(&rng.uniform_mat(4, 4)).unwrap();
        // premature finish rejected
        let w2 = NmfStoreWriter::create(&tmp("short.nmfstore"), 2, 5, 2).unwrap();
        assert!(w2.finish().is_err());
        w.write_block(&rng.uniform_mat(4, 2)).unwrap(); // final short block
        w.finish().unwrap();
        assert_eq!(NmfStore::open(&path).unwrap().cols(), 10);
    }

    #[test]
    fn read_block_into_matches_read_cols_any_range() {
        let mut rng = Pcg64::seed_from_u64(7);
        let m = rng.uniform_mat(11, 29);
        let path = tmp("block_into.nmfstore");
        write_mat(&path, &m, 6).unwrap();
        let store = NmfStore::open(&path).unwrap();
        // One reusable buffer across aligned, straddling, and short ranges.
        let mut buf = crate::linalg::mat::Mat::zeros(1, 1);
        for (j0, j1) in [(0, 6), (6, 12), (24, 29), (0, 29), (4, 9), (5, 23), (28, 29)] {
            store.read_block_into(j0, j1, &mut buf).unwrap();
            assert_eq!(buf, m.col_block(j0, j1), "{j0}..{j1}");
        }
        assert!(store.read_block_into(3, 3, &mut buf).is_err());
        assert!(store.read_block_into(0, 30, &mut buf).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmp("bad.nmfstore");
        std::fs::write(&path, b"NOTASTORExxxxxxxxxxxxxxxxxxxxxxx").unwrap();
        assert!(NmfStore::open(&path).is_err());
    }

    fn sparse_fixture(m: usize, n: usize, seed: u64) -> (Mat, CscMat) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let dense = rng.uniform_mat(m, n).map(|v| if v < 0.7 { 0.0 } else { v });
        let csc = CscMat::from_csr(&crate::linalg::sparse::CsrMat::from_dense(&dense));
        (dense, csc)
    }

    #[test]
    fn sparse_store_roundtrip_exact() {
        let (_dense, csc) = sparse_fixture(17, 23, 10);
        let path = tmp("sparse_roundtrip.nmfstore");
        write_csc(&path, &csc, 5).unwrap();
        let store = SparseNmfStore::open(&path).unwrap();
        assert_eq!(store.rows(), 17);
        assert_eq!(store.cols(), 23);
        assert_eq!(store.block_width(), 5);
        assert_eq!(SparseColumnBlockSource::nnz(&store), csc.nnz());
        assert_eq!(store.read_all().unwrap(), csc);
    }

    #[test]
    fn sparse_store_arbitrary_column_ranges() {
        let (_dense, csc) = sparse_fixture(9, 31, 11);
        let path = tmp("sparse_ranges.nmfstore");
        write_csc(&path, &csc, 7).unwrap();
        let store = SparseNmfStore::open(&path).unwrap();
        let mut block = CscBlock::new();
        for (j0, j1) in [(0, 31), (0, 1), (30, 31), (3, 11), (6, 8), (13, 29)] {
            block.clear();
            store.read_block_into(j0, j1, &mut block).unwrap();
            assert_eq!(block.ncols(), j1 - j0, "{j0}..{j1}");
            for j in j0..j1 {
                let (is, vs) = block.col(j - j0);
                let (eis, evs) = csc.col(j);
                assert_eq!(is, eis, "col {j}: rows");
                assert_eq!(vs, evs, "col {j}: values");
            }
        }
        block.clear();
        assert!(store.read_block_into(0, 32, &mut block).is_err());
        // Empty range is a no-op append (the chunk assembler relies on
        // range semantics j0 <= j1).
        assert!(store.read_block_into(5, 5, &mut block).is_ok());
        assert_eq!(block.ncols(), 0);
    }

    #[test]
    fn sparse_store_writer_validates() {
        let path = tmp("sparse_stream.nmfstore");
        let mut w = SparseNmfStoreWriter::create(&path, 6, 3, 2).unwrap();
        w.append_col(&[0, 4], &[1.0, 2.0]).unwrap();
        // Unsorted / OOB / non-finite / ragged columns rejected.
        assert!(w.append_col(&[3, 1], &[1.0, 2.0]).is_err(), "descending rows");
        assert!(w.append_col(&[6], &[1.0]).is_err(), "row out of bounds");
        assert!(w.append_col(&[1], &[f64::NAN]).is_err(), "non-finite value");
        assert!(w.append_col(&[1, 2], &[1.0]).is_err(), "ragged column");
        w.append_col(&[], &[]).unwrap();
        // Premature finish rejected.
        let w2 = SparseNmfStoreWriter::create(&tmp("sparse_short.nmfstore"), 2, 5, 2).unwrap();
        assert!(w2.finish().is_err());
        w.append_col(&[5], &[3.0]).unwrap();
        assert!(w.append_col(&[0], &[1.0]).is_err(), "extra column rejected");
        w.finish().unwrap();
        let store = SparseNmfStore::open(&path).unwrap();
        assert_eq!(SparseColumnBlockSource::nnz(&store), 3);
        // Dense magic is rejected by the sparse opener and vice versa.
        let dense_path = tmp("dense_for_magic.nmfstore");
        write_mat(&dense_path, &Mat::full(2, 2, 1.0), 1).unwrap();
        assert!(SparseNmfStore::open(&dense_path).is_err());
        assert!(NmfStore::open(&path).is_err());
    }

    #[test]
    fn sparse_store_corrupt_payload_errors_not_panics() {
        // A file whose colptr is consistent but whose payload carries an
        // out-of-bounds row index must surface as Err at read time —
        // never as a panic inside a downstream kernel.
        let (_dense, csc) = sparse_fixture(8, 6, 14);
        assert!(csc.nnz() > 0);
        let path = tmp("sparse_corrupt.nmfstore");
        write_csc(&path, &csc, 3).unwrap();
        // Overwrite the first payload entry's row with rows + 7.
        let payload_off = 40 + (6 + 1) * 8;
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[payload_off..payload_off + 8].copy_from_slice(&15u64.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let store = SparseNmfStore::open(&path).unwrap();
        let mut block = CscBlock::new();
        let err = store.read_block_into(0, 6, &mut block);
        assert!(err.is_err(), "OOB payload row must be an Err");
        assert!(store.read_all().is_err());
    }

    #[test]
    fn dense_crc_footer_catches_slab_bit_flip() {
        let mut rng = Pcg64::seed_from_u64(21);
        let m = rng.uniform_mat(7, 12);
        let path = tmp("dense_rot.nmfstore");
        write_mat(&path, &m, 5).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip a low mantissa bit deep inside the second slab: the value
        // stays finite, so only the slab CRC can catch it.
        let pos = 32 + 7 * 5 * 8 + 24;
        bytes[pos] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let store = NmfStore::open(&path).unwrap(); // header intact
        let err = store.read_all().unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
        assert_eq!(robust::classify(&err), robust::FaultKind::Corrupt);
        assert!(store.verify_integrity().is_err());
        // The untouched first slab still reads clean.
        assert_eq!(store.read_cols(0, 5).unwrap(), m.col_block(0, 5));
    }

    #[test]
    fn legacy_footerless_dense_store_still_reads() {
        let mut rng = Pcg64::seed_from_u64(22);
        let m = rng.uniform_mat(6, 9);
        let path = tmp("dense_legacy.nmfstore");
        write_mat(&path, &m, 4).unwrap();
        let plain = 32 + 6 * 9 * 8;
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.len() > plain, "writer must emit a footer");
        std::fs::write(&path, &bytes[..plain]).unwrap();
        let store = NmfStore::open(&path).unwrap();
        assert_eq!(store.read_all().unwrap(), m);
        store.verify_integrity().unwrap();
    }

    #[test]
    fn truncated_dense_store_rejected_at_open() {
        let mut rng = Pcg64::seed_from_u64(23);
        let m = rng.uniform_mat(6, 9);
        let path = tmp("dense_trunc.nmfstore");
        write_mat(&path, &m, 4).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 11]).unwrap();
        let err = NmfStore::open(&path).unwrap_err();
        assert_eq!(robust::classify(&err), robust::FaultKind::Corrupt);
    }

    #[test]
    fn sparse_crc_footer_and_scrub() {
        let (_dense, csc) = sparse_fixture(10, 9, 31);
        assert!(csc.nnz() > 1);
        let path = tmp("sparse_rot.nmfstore");
        write_csc(&path, &csc, 4).unwrap();
        let clean = std::fs::read(&path).unwrap();
        let store = SparseNmfStore::open(&path).unwrap();
        store.verify_integrity().unwrap();
        drop(store);

        // Bit rot in a payload *value* passes the structural row checks;
        // only the checksum scrub can catch it.
        let payload_off = 40 + (9 + 1) * 8;
        let mut bytes = clean.clone();
        bytes[payload_off + 8] ^= 0x01; // low mantissa bit of first value
        std::fs::write(&path, &bytes).unwrap();
        let store = SparseNmfStore::open(&path).unwrap();
        let err = store.verify_integrity().unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
        assert_eq!(robust::classify(&err), robust::FaultKind::Corrupt);
        drop(store);

        // Bit rot in the column pointer is caught at open.
        let mut bytes = clean.clone();
        bytes[40 + 8] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        assert!(SparseNmfStore::open(&path).is_err());

        // Legacy footer-less file still opens, reads, and scrubs.
        std::fs::write(&path, &clean[..clean.len() - 20]).unwrap();
        let store = SparseNmfStore::open(&path).unwrap();
        assert_eq!(store.read_all().unwrap(), csc);
        store.verify_integrity().unwrap();
    }

    #[test]
    fn out_of_core_sparse_qb_matches_in_memory_bitwise() {
        use crate::sketch::blocked::{qb_blocked_sparse, CscSource};
        use crate::sketch::qb::QbOptions;
        let (dense, csc) = sparse_fixture(40, 33, 12);
        let path = tmp("sparse_qb.nmfstore");
        write_csc(&path, &csc, 8).unwrap();
        let store = SparseNmfStore::open(&path).unwrap();
        let opts = QbOptions::new(5).with_oversample(6).with_power_iters(1);
        let mut r1 = Pcg64::seed_from_u64(13);
        let mut r2 = Pcg64::seed_from_u64(13);
        let from_disk = qb_blocked_sparse(&store, opts, 8, &mut r1).unwrap();
        let from_mem = qb_blocked_sparse(&CscSource(&csc), opts, 8, &mut r2).unwrap();
        assert_eq!(from_disk.q, from_mem.q, "disk and memory sources must bit-match");
        assert_eq!(from_disk.b, from_mem.b);
        assert!(from_disk.relative_error(&dense) < 1e-6);
    }

    #[test]
    fn out_of_core_qb_matches_in_memory() {
        use crate::sketch::blocked::{qb_blocked, MatSource};
        use crate::sketch::qb::QbOptions;
        let mut rng = Pcg64::seed_from_u64(4);
        let u = rng.uniform_mat(40, 5);
        let v = rng.uniform_mat(5, 33);
        let m = crate::linalg::gemm::matmul(&u, &v);
        let path = tmp("qb.nmfstore");
        write_mat(&path, &m, 8).unwrap();
        let store = NmfStore::open(&path).unwrap();
        let opts = QbOptions::new(5).with_oversample(6).with_power_iters(1);
        let mut r1 = Pcg64::seed_from_u64(5);
        let mut r2 = Pcg64::seed_from_u64(5);
        let from_disk = qb_blocked(&store, opts, 8, &mut r1).unwrap();
        let from_mem = qb_blocked(&MatSource(&m), opts, 8, &mut r2).unwrap();
        assert!(from_disk.q.max_abs_diff(&from_mem.q) < 1e-12);
        assert!(from_disk.b.max_abs_diff(&from_mem.b) < 1e-12);
        assert!(from_disk.relative_error(&m) < 1e-8);
    }
}

//! `.nmfstore` — the column-blocked on-disk matrix store.
//!
//! The paper's out-of-core discussion (Appendix A) assumes an HDF5-style
//! container that can hand back subsets of columns without touching the
//! rest of the file. This is our substitute: a flat binary format whose
//! unit of I/O is a **column block**, so the blocked QB algorithm streams
//! `2 + 2q` sequential passes with `O(m·block)` memory.
//!
//! Layout (little-endian):
//!
//! ```text
//! magic    8 bytes  "NMFSTOR1"
//! rows     u64
//! cols     u64
//! block    u64                  column-block width
//! data     ⌈cols/block⌉ blocks, each a rows×bw row-major f64 slab
//! ```
//!
//! Reads use `pread` (`FileExt::read_exact_at`), so a shared `&NmfStore`
//! can serve concurrent readers without seek races.

use std::fs::File;
use std::io::Write;
use std::os::unix::fs::FileExt;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::linalg::mat::Mat;
use crate::sketch::blocked::ColumnBlockSource;

const MAGIC: &[u8; 8] = b"NMFSTOR1";

/// Read handle for a `.nmfstore` file.
pub struct NmfStore {
    file: File,
    rows: usize,
    cols: usize,
    block: usize,
    /// Reusable slab staging for `read_block_into`'s misaligned path:
    /// grown once to the native slab size, then reused, so the
    /// out-of-core reader performs one `pread` per slab and zero
    /// steady-state allocations. Behind a mutex because reads take
    /// `&self`; only the blocked-QB driver (single-threaded) uses it, so
    /// contention is nil and `read_cols`' concurrent readers are
    /// unaffected (they allocate their own slabs as before).
    slab_scratch: Mutex<Vec<f64>>,
}

impl NmfStore {
    /// Open an existing store.
    pub fn open(path: &Path) -> Result<NmfStore> {
        let file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let mut header = [0u8; 32];
        file.read_exact_at(&mut header, 0).context("reading header")?;
        if &header[0..8] != MAGIC {
            bail!("{} is not an nmfstore file", path.display());
        }
        let rows = u64::from_le_bytes(header[8..16].try_into().unwrap()) as usize;
        let cols = u64::from_le_bytes(header[16..24].try_into().unwrap()) as usize;
        let block = u64::from_le_bytes(header[24..32].try_into().unwrap()) as usize;
        if block == 0 || rows == 0 || cols == 0 {
            bail!("degenerate store dimensions {rows}x{cols} block {block}");
        }
        Ok(NmfStore { file, rows, cols, block, slab_scratch: Mutex::new(Vec::new()) })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Native block width (reads at this granularity are single-slab).
    pub fn block_width(&self) -> usize {
        self.block
    }

    /// Byte offset of block `bi` (blocks before it are all full except
    /// possibly none — only the last block is short).
    fn block_offset(&self, bi: usize) -> u64 {
        32 + (bi * self.block * self.rows * 8) as u64
    }

    fn block_cols_of(&self, bi: usize) -> usize {
        let j0 = bi * self.block;
        (self.cols - j0).min(self.block)
    }

    /// Read one whole native block as a rows×bw matrix.
    pub fn read_native_block(&self, bi: usize) -> Result<Mat> {
        let bw = self.block_cols_of(bi);
        anyhow::ensure!(bw > 0, "block index {bi} out of range");
        let nbytes = self.rows * bw * 8;
        let mut buf = vec![0u8; nbytes];
        self.file
            .read_exact_at(&mut buf, self.block_offset(bi))
            .with_context(|| format!("reading block {bi}"))?;
        let data: Vec<f64> = buf
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Mat::from_vec(self.rows, bw, data))
    }

    /// Read an arbitrary column range `[j0, j1)` (slices native blocks).
    pub fn read_cols(&self, j0: usize, j1: usize) -> Result<Mat> {
        anyhow::ensure!(j0 < j1 && j1 <= self.cols, "bad column range {j0}..{j1}");
        let mut out = Mat::zeros(self.rows, j1 - j0);
        let mut bi = j0 / self.block;
        loop {
            let b0 = bi * self.block;
            if b0 >= j1 {
                break;
            }
            let blk = self.read_native_block(bi)?;
            let lo = j0.max(b0);
            let hi = j1.min(b0 + blk.cols());
            let piece = blk.col_block(lo - b0, hi - b0);
            out.set_col_block(lo - j0, &piece);
            bi += 1;
        }
        Ok(out)
    }

    /// Materialize the full matrix (small stores / tests only).
    pub fn read_all(&self) -> Result<Mat> {
        self.read_cols(0, self.cols)
    }
}

/// View an `f64` slice as raw little-endian-file bytes for `pread`ing
/// straight into matrix storage (no staging buffer, no allocation).
fn as_bytes_mut(s: &mut [f64]) -> &mut [u8] {
    // SAFETY: f64 and [u8; 8] have no invalid bit patterns; the slice
    // covers exactly the same memory. Callers fix endianness afterwards.
    unsafe { std::slice::from_raw_parts_mut(s.as_mut_ptr() as *mut u8, s.len() * 8) }
}

/// Reinterpret bytes just `pread` into `s` as little-endian `f64`s, in
/// place (no-op on little-endian hosts).
fn fix_le_in_place(s: &mut [f64]) {
    for v in s {
        *v = f64::from_bits(u64::from_le((*v).to_bits()));
    }
}

impl ColumnBlockSource for NmfStore {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    fn read_block(&self, j0: usize, j1: usize) -> Result<Mat> {
        self.read_cols(j0, j1)
    }

    /// Allocation-free block read: a block-aligned range is `pread`
    /// directly into `out`'s storage; a misaligned range reads each
    /// overlapped slab whole into the store's reusable staging buffer and
    /// copies the needed column segments out. Either way: one contiguous
    /// read per slab, endian-fix in place, zero steady-state allocations
    /// once the buffers are warm — what the out-of-core QB path relies on.
    fn read_block_into(&self, j0: usize, j1: usize, out: &mut Mat) -> Result<()> {
        anyhow::ensure!(j0 < j1 && j1 <= self.cols, "bad column range {j0}..{j1}");
        let w = j1 - j0;
        out.resize(self.rows, w);
        // Fast path: the range is exactly one whole native block — the
        // on-disk slab layout matches `out` row-major, one contiguous read.
        if j0 % self.block == 0 && self.block_cols_of(j0 / self.block) == w {
            let bi = j0 / self.block;
            self.file
                .read_exact_at(as_bytes_mut(out.as_mut_slice()), self.block_offset(bi))
                .with_context(|| format!("reading block {bi}"))?;
            fix_le_in_place(out.as_mut_slice());
            return Ok(());
        }
        // General path: one whole-slab `pread` per overlapped native
        // block into the reusable staging buffer, then copy the needed
        // column segments out row by row.
        let mut scratch = self.slab_scratch.lock().unwrap_or_else(|e| e.into_inner());
        let mut bi = j0 / self.block;
        loop {
            let b0 = bi * self.block;
            if b0 >= j1 {
                break;
            }
            let bw = self.block_cols_of(bi);
            let lo = j0.max(b0);
            let hi = j1.min(b0 + bw);
            scratch.resize(self.rows * bw, 0.0);
            self.file
                .read_exact_at(as_bytes_mut(&mut scratch[..]), self.block_offset(bi))
                .with_context(|| format!("reading block {bi}"))?;
            fix_le_in_place(&mut scratch[..]);
            for i in 0..self.rows {
                let src = &scratch[i * bw + (lo - b0)..i * bw + (hi - b0)];
                out.row_mut(i)[lo - j0..hi - j0].copy_from_slice(src);
            }
            bi += 1;
        }
        Ok(())
    }
}

/// Incremental writer: blocks are appended in order, so a generator can
/// stream a matrix to disk without materializing it.
pub struct NmfStoreWriter {
    file: File,
    rows: usize,
    cols: usize,
    block: usize,
    written_cols: usize,
}

impl NmfStoreWriter {
    pub fn create(path: &Path, rows: usize, cols: usize, block: usize) -> Result<NmfStoreWriter> {
        anyhow::ensure!(rows > 0 && cols > 0 && block > 0, "degenerate store shape");
        let mut file =
            File::create(path).with_context(|| format!("creating {}", path.display()))?;
        file.write_all(MAGIC)?;
        file.write_all(&(rows as u64).to_le_bytes())?;
        file.write_all(&(cols as u64).to_le_bytes())?;
        file.write_all(&(block as u64).to_le_bytes())?;
        Ok(NmfStoreWriter { file, rows, cols, block, written_cols: 0 })
    }

    /// Append the next column block. Must be `block` wide except the last.
    pub fn write_block(&mut self, m: &Mat) -> Result<()> {
        anyhow::ensure!(m.rows() == self.rows, "row mismatch");
        let expected = (self.cols - self.written_cols).min(self.block);
        anyhow::ensure!(
            m.cols() == expected,
            "block width {} != expected {expected}",
            m.cols()
        );
        let mut buf = Vec::with_capacity(m.len() * 8);
        for &v in m.as_slice() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.file.write_all(&buf)?;
        self.written_cols += m.cols();
        Ok(())
    }

    /// Finish; errors if the column count is short.
    pub fn finish(mut self) -> Result<()> {
        anyhow::ensure!(
            self.written_cols == self.cols,
            "store incomplete: {}/{} columns written",
            self.written_cols,
            self.cols
        );
        self.file.flush()?;
        Ok(())
    }
}

/// Write an in-memory matrix as a store (tests and small data).
pub fn write_mat(path: &Path, m: &Mat, block: usize) -> Result<()> {
    let mut w = NmfStoreWriter::create(path, m.rows(), m.cols(), block)?;
    let mut j0 = 0;
    while j0 < m.cols() {
        let j1 = (j0 + block).min(m.cols());
        w.write_block(&m.col_block(j0, j1))?;
        j0 = j1;
    }
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rng::Pcg64;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("randnmf_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_exact() {
        let mut rng = Pcg64::seed_from_u64(1);
        let m = rng.uniform_mat(17, 23);
        let path = tmp("roundtrip.nmfstore");
        write_mat(&path, &m, 5).unwrap();
        let store = NmfStore::open(&path).unwrap();
        assert_eq!(store.rows(), 17);
        assert_eq!(store.cols(), 23);
        assert_eq!(store.block_width(), 5);
        assert_eq!(store.read_all().unwrap(), m);
    }

    #[test]
    fn arbitrary_column_ranges() {
        let mut rng = Pcg64::seed_from_u64(2);
        let m = rng.uniform_mat(9, 31);
        let path = tmp("ranges.nmfstore");
        write_mat(&path, &m, 7).unwrap();
        let store = NmfStore::open(&path).unwrap();
        for (j0, j1) in [(0, 31), (0, 1), (30, 31), (3, 11), (6, 8), (7, 14), (13, 29)] {
            assert_eq!(store.read_cols(j0, j1).unwrap(), m.col_block(j0, j1), "{j0}..{j1}");
        }
        assert!(store.read_cols(5, 5).is_err());
        assert!(store.read_cols(0, 32).is_err());
    }

    #[test]
    fn streaming_writer_validates() {
        let path = tmp("stream.nmfstore");
        let mut w = NmfStoreWriter::create(&path, 4, 10, 4).unwrap();
        let mut rng = Pcg64::seed_from_u64(3);
        w.write_block(&rng.uniform_mat(4, 4)).unwrap();
        // wrong width rejected
        assert!(w.write_block(&rng.uniform_mat(4, 3)).is_err());
        w.write_block(&rng.uniform_mat(4, 4)).unwrap();
        // premature finish rejected
        let w2 = NmfStoreWriter::create(&tmp("short.nmfstore"), 2, 5, 2).unwrap();
        assert!(w2.finish().is_err());
        w.write_block(&rng.uniform_mat(4, 2)).unwrap(); // final short block
        w.finish().unwrap();
        assert_eq!(NmfStore::open(&path).unwrap().cols(), 10);
    }

    #[test]
    fn read_block_into_matches_read_cols_any_range() {
        let mut rng = Pcg64::seed_from_u64(7);
        let m = rng.uniform_mat(11, 29);
        let path = tmp("block_into.nmfstore");
        write_mat(&path, &m, 6).unwrap();
        let store = NmfStore::open(&path).unwrap();
        // One reusable buffer across aligned, straddling, and short ranges.
        let mut buf = crate::linalg::mat::Mat::zeros(1, 1);
        for (j0, j1) in [(0, 6), (6, 12), (24, 29), (0, 29), (4, 9), (5, 23), (28, 29)] {
            store.read_block_into(j0, j1, &mut buf).unwrap();
            assert_eq!(buf, m.col_block(j0, j1), "{j0}..{j1}");
        }
        assert!(store.read_block_into(3, 3, &mut buf).is_err());
        assert!(store.read_block_into(0, 30, &mut buf).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmp("bad.nmfstore");
        std::fs::write(&path, b"NOTASTORExxxxxxxxxxxxxxxxxxxxxxx").unwrap();
        assert!(NmfStore::open(&path).is_err());
    }

    #[test]
    fn out_of_core_qb_matches_in_memory() {
        use crate::sketch::blocked::{qb_blocked, MatSource};
        use crate::sketch::qb::QbOptions;
        let mut rng = Pcg64::seed_from_u64(4);
        let u = rng.uniform_mat(40, 5);
        let v = rng.uniform_mat(5, 33);
        let m = crate::linalg::gemm::matmul(&u, &v);
        let path = tmp("qb.nmfstore");
        write_mat(&path, &m, 8).unwrap();
        let store = NmfStore::open(&path).unwrap();
        let opts = QbOptions::new(5).with_oversample(6).with_power_iters(1);
        let mut r1 = Pcg64::seed_from_u64(5);
        let mut r2 = Pcg64::seed_from_u64(5);
        let from_disk = qb_blocked(&store, opts, 8, &mut r1).unwrap();
        let from_mem = qb_blocked(&MatSource(&m), opts, 8, &mut r2).unwrap();
        assert!(from_disk.q.max_abs_diff(&from_mem.q) < 1e-12);
        assert!(from_disk.b.max_abs_diff(&from_mem.b) < 1e-12);
        assert!(from_disk.relative_error(&m) < 1e-8);
    }
}

//! Synthetic hyperspectral scene — substitute for the HYDICE 'urban'
//! image (paper §4.2, Table 2, Figs. 7–9; see DESIGN.md §5).
//!
//! Blind hyperspectral unmixing assumes the **linear mixing model**
//! `X = W·H`: each pixel's spectrum is a nonnegative combination of a few
//! pure endmember spectra weighted by abundances. We generate directly
//! from that model — four endmembers (the paper's asphalt / grass / tree /
//! roof), smooth Gaussian-bump spectral signatures over 162 bands, and
//! spatially coherent abundance maps (per-class blobs, simplex-normalized
//! per pixel) — so recovery is quantitatively checkable via spectral-angle
//! distance, which the real-data experiment can only eyeball.

use crate::linalg::gemm;
use crate::linalg::mat::Mat;
use crate::linalg::norms::vec_norm;
use crate::linalg::rng::Pcg64;

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct HyperspectralSpec {
    /// Spectral bands (paper: 162 after water-vapor channels removed).
    pub bands: usize,
    /// Scene side length in pixels (paper: 307 → 94,249 pixels).
    pub side: usize,
    /// Endmembers (paper: 4 — asphalt, grass, tree, roof).
    pub endmembers: usize,
    pub noise: f64,
    pub seed: u64,
}

impl HyperspectralSpec {
    /// Paper-scale: 162 × 94,249.
    pub fn paper() -> Self {
        HyperspectralSpec { bands: 162, side: 307, endmembers: 4, noise: 0.01, seed: 42 }
    }

    pub fn small() -> Self {
        HyperspectralSpec { bands: 40, side: 32, endmembers: 4, noise: 0.01, seed: 42 }
    }

    pub fn pixels(&self) -> usize {
        self.side * self.side
    }
}

/// Generated scene with ground truth.
pub struct HyperspectralData {
    /// bands × pixels data matrix.
    pub x: Mat,
    /// Ground-truth endmember spectra, bands × endmembers.
    pub endmembers: Mat,
    /// Ground-truth abundances, endmembers × pixels (rows sum ≈ 1 per col).
    pub abundances: Mat,
    pub spec: HyperspectralSpec,
}

/// Generate the scene.
pub fn generate(spec: &HyperspectralSpec) -> HyperspectralData {
    let mut rng = Pcg64::seed_from_u64(spec.seed);
    let k = spec.endmembers;
    let npix = spec.pixels();

    // --- Endmember spectra: 2-3 smooth Gaussian bumps per signature ---
    // Each endmember's dominant bump lives in its own region of the
    // spectrum (materials like asphalt/grass/tree/roof have distinctive
    // reflectance peaks); secondary bumps may overlap. This keeps the
    // unmixing identifiable, like real urban endmembers are.
    let mut endmembers = Mat::zeros(spec.bands, k);
    for j in 0..k {
        let mut sig = vec![0.02f64; spec.bands];
        // dominant bump centered in endmember j's own region
        let region = spec.bands as f64 / k as f64;
        let center = (j as f64 + 0.3 + 0.4 * rng.uniform()) * region;
        let width = (0.4 + 0.3 * rng.uniform()) * region;
        for (b, s) in sig.iter_mut().enumerate() {
            *s += (1.0 + 0.3 * rng.uniform())
                * (-0.5 * ((b as f64 - center) / width).powi(2)).exp();
        }
        // 1-2 weaker bumps anywhere
        for _ in 0..(1 + rng.uniform_usize(2)) {
            let c2 = rng.uniform() * spec.bands as f64;
            let w2 = (0.05 + 0.1 * rng.uniform()) * spec.bands as f64;
            let a2 = 0.1 + 0.2 * rng.uniform();
            for (b, s) in sig.iter_mut().enumerate() {
                *s += a2 * (-0.5 * ((b as f64 - c2) / w2).powi(2)).exp();
            }
        }
        let nrm = vec_norm(&sig).max(1e-12);
        for (b, s) in sig.iter().enumerate() {
            endmembers.set(b, j, s / nrm);
        }
    }

    // --- Abundance maps: per-class spatial Gaussian blobs, normalized ---
    let mut raw = Mat::zeros(k, npix);
    for j in 0..k {
        let blobs = 3 + rng.uniform_usize(4);
        let mut field = vec![0.02f64; npix];
        for _ in 0..blobs {
            let cy = rng.uniform() * spec.side as f64;
            let cx = rng.uniform() * spec.side as f64;
            let sy = (0.05 + 0.15 * rng.uniform()) * spec.side as f64;
            let sx = (0.05 + 0.15 * rng.uniform()) * spec.side as f64;
            let amp = 0.5 + rng.uniform();
            for y in 0..spec.side {
                for x in 0..spec.side {
                    let d = ((y as f64 - cy) / sy).powi(2) + ((x as f64 - cx) / sx).powi(2);
                    field[y * spec.side + x] += amp * (-0.5 * d).exp();
                }
            }
        }
        for (p, f) in field.iter().enumerate() {
            raw.set(j, p, *f);
        }
    }
    // Sharpen the fields (cube) so most pixels are near-pure — real urban
    // scenes have large single-material regions, and identifiability of the
    // unregularized NMF unmixing depends on near-pure pixels existing.
    let mut abundances = raw;
    abundances.map_inplace(|v| v * v * v);
    for p in 0..npix {
        let total: f64 = (0..k).map(|j| abundances.get(j, p)).sum();
        if total > 0.0 {
            for j in 0..k {
                let v = abundances.get(j, p) / total;
                abundances.set(j, p, v);
            }
        }
    }

    // --- X = W·H + nonnegative noise ---
    let mut x = gemm::matmul(&endmembers, &abundances);
    if spec.noise > 0.0 {
        let scale = spec.noise * x.sum() / x.len() as f64;
        for v in x.as_mut_slice() {
            *v += scale * rng.uniform();
        }
    }

    HyperspectralData { x, endmembers, abundances, spec: spec.clone() }
}

/// Mean spectral-angle distance (radians) between recovered and true
/// endmembers under the best greedy matching — the quantitative version of
/// the paper's Fig. 7 visual check. 0 = perfect.
pub fn spectral_angle_distance(recovered: &Mat, truth: &Mat) -> f64 {
    let kt = truth.cols();
    let kr = recovered.cols();
    if kt == 0 || kr == 0 {
        return std::f64::consts::FRAC_PI_2;
    }
    let mut used = vec![false; kr];
    let mut total = 0.0;
    for tj in 0..kt {
        let t = truth.col(tj);
        let tn = vec_norm(&t).max(1e-12);
        let mut best = -1.0;
        let mut best_i = None;
        for rj in 0..kr {
            if used[rj] {
                continue;
            }
            let r = recovered.col(rj);
            let rn = vec_norm(&r).max(1e-12);
            let cos: f64 = t.iter().zip(r.iter()).map(|(a, b)| a * b).sum::<f64>() / (tn * rn);
            if cos > best {
                best = cos;
                best_i = Some(rj);
            }
        }
        if let Some(i) = best_i {
            used[i] = true;
        }
        total += best.clamp(-1.0, 1.0).acos();
    }
    total / kt as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_simplex() {
        let spec = HyperspectralSpec { bands: 20, side: 8, endmembers: 4, noise: 0.01, seed: 1 };
        let d = generate(&spec);
        assert_eq!(d.x.shape(), (20, 64));
        assert_eq!(d.endmembers.shape(), (20, 4));
        assert_eq!(d.abundances.shape(), (4, 64));
        assert!(d.x.is_nonneg());
        for p in 0..64 {
            let s: f64 = (0..4).map(|j| d.abundances.get(j, p)).sum();
            assert!((s - 1.0).abs() < 1e-9, "abundances must sum to 1, got {s}");
        }
    }

    #[test]
    fn deterministic() {
        let spec = HyperspectralSpec::small();
        assert_eq!(generate(&spec).x, generate(&spec).x);
    }

    #[test]
    fn sad_zero_for_exact_match() {
        let d = generate(&HyperspectralSpec::small());
        assert!(spectral_angle_distance(&d.endmembers, &d.endmembers) < 1e-6);
    }

    #[test]
    fn nmf_recovers_endmembers() {
        let spec = HyperspectralSpec { bands: 30, side: 16, endmembers: 4, noise: 0.005, seed: 2 };
        let d = generate(&spec);
        let fit = crate::nmf::hals::Hals::new(
            crate::nmf::options::NmfOptions::new(4)
                .with_max_iter(400)
                .with_seed(3)
                .with_init(crate::nmf::options::Init::NndsvdA),
        )
        .fit(&d.x)
        .unwrap();
        let sad = spectral_angle_distance(&fit.model.w, &d.endmembers);
        // Random spectra pairs are ~60-90° apart; recovery well under 25°.
        assert!(sad < 0.45, "spectral angle distance {sad} too large");
    }
}

//! Datasets and storage.
//!
//! Synthetic substitutes for the paper's three real datasets (DESIGN.md §5
//! documents each substitution) plus the generators for §4.4's synthetic
//! benchmarks and the out-of-core column-block store:
//!
//! * [`synthetic`] — exact-rank nonnegative matrices (paper §4.4).
//! * [`faces`] — parts-based face images (Yale-B substitute).
//! * [`hyperspectral`] — linear-mixing-model scene ('urban' substitute).
//! * [`digits`] — stroke-rendered labeled digits (MNIST substitute).
//! * [`store`] — `.nmfstore` column-blocked binary format (HDF5
//!   substitute), dense slabs plus the sparse CSC-slab extension
//!   ([`store::SparseNmfStore`]) for `O(nnz)`-I/O streaming.
//! * [`robust`] — CRC32, the `Corrupt`/`Transient`/`Fatal` fault
//!   taxonomy, and hardened pread/pwrite wrappers with bounded retry.

pub mod digits;
pub mod faces;
pub mod hyperspectral;
pub mod robust;
pub mod store;
pub mod synthetic;

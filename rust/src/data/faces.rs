//! Synthetic faces dataset — substitute for the cropped Yale face
//! database B (see DESIGN.md §5).
//!
//! The Yale-B experiment (paper §4.1, Table 1, Figs. 4–6) tests whether
//! NMF recovers **parts-based structure** from a tall dense nonnegative
//! matrix. This generator produces images that are additive nonnegative
//! combinations of `n_parts` spatially localized templates (eyes, brows,
//! nose, mouth, cheeks, jaw — Gaussian blobs at canonical positions), with
//! per-image illumination scaling and sensor noise, matching the
//! structural property the experiment measures while staying fully
//! reproducible from a seed.
//!
//! Default dimensions mirror the paper: 192×168 images (32,256 pixels),
//! 2,410 images.

use crate::linalg::mat::Mat;
use crate::linalg::norms::vec_norm;
use crate::linalg::rng::Pcg64;

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct FacesSpec {
    pub height: usize,
    pub width: usize,
    pub n_images: usize,
    /// Number of latent parts (the paper extracts k = 16 features).
    pub n_parts: usize,
    /// Relative sensor-noise level.
    pub noise: f64,
    pub seed: u64,
}

impl FacesSpec {
    /// Paper-scale dataset: 32,256 × 2,410.
    pub fn paper() -> Self {
        FacesSpec { height: 192, width: 168, n_images: 2410, n_parts: 16, noise: 0.02, seed: 42 }
    }

    /// Small variant for tests/examples.
    pub fn small() -> Self {
        FacesSpec { height: 48, width: 42, n_images: 200, n_parts: 8, noise: 0.02, seed: 42 }
    }

    pub fn pixels(&self) -> usize {
        self.height * self.width
    }
}

/// Generated dataset: `x` is pixels×images; `parts` the ground-truth
/// templates (pixels×n_parts), each ℓ2-normalized.
pub struct FacesData {
    pub x: Mat,
    pub parts: Mat,
    pub spec: FacesSpec,
}

/// Canonical facial-part anchor positions in unit coordinates
/// `(row, col, row_sigma, col_sigma)`.
const ANCHORS: &[(f64, f64, f64, f64)] = &[
    (0.32, 0.30, 0.05, 0.08), // left eye
    (0.32, 0.70, 0.05, 0.08), // right eye
    (0.22, 0.30, 0.03, 0.10), // left brow
    (0.22, 0.70, 0.03, 0.10), // right brow
    (0.52, 0.50, 0.10, 0.05), // nose
    (0.72, 0.50, 0.05, 0.12), // mouth
    (0.55, 0.18, 0.10, 0.06), // left cheek
    (0.55, 0.82, 0.10, 0.06), // right cheek
    (0.88, 0.50, 0.07, 0.18), // jaw
    (0.08, 0.50, 0.06, 0.20), // forehead
    (0.40, 0.50, 0.04, 0.04), // nose bridge
    (0.62, 0.32, 0.05, 0.05), // left nostril area
    (0.62, 0.68, 0.05, 0.05), // right nostril area
    (0.80, 0.30, 0.06, 0.07), // left chin
    (0.80, 0.70, 0.06, 0.07), // right chin
    (0.45, 0.05, 0.20, 0.04), // left face edge
    (0.45, 0.95, 0.20, 0.04), // right face edge
    (0.15, 0.15, 0.06, 0.06), // left temple
    (0.15, 0.85, 0.06, 0.06), // right temple
    (0.95, 0.50, 0.04, 0.10), // lower jawline
];

/// Generate the dataset.
pub fn generate(spec: &FacesSpec) -> FacesData {
    let mut rng = Pcg64::seed_from_u64(spec.seed);
    let p = spec.pixels();
    let k = spec.n_parts;
    assert!(k <= ANCHORS.len(), "at most {} parts supported", ANCHORS.len());

    // Templates: Gaussian blobs (slightly jittered per dataset seed).
    let mut parts = Mat::zeros(p, k);
    for j in 0..k {
        let (r0, c0, sr, sc) = ANCHORS[j];
        let jr = r0 + 0.02 * rng.gaussian();
        let jc = c0 + 0.02 * rng.gaussian();
        let mut col = vec![0.0f64; p];
        for row in 0..spec.height {
            let y = (row as f64 + 0.5) / spec.height as f64;
            for cx in 0..spec.width {
                let x = (cx as f64 + 0.5) / spec.width as f64;
                let d = ((y - jr) / sr).powi(2) + ((x - jc) / sc).powi(2);
                col[row * spec.width + cx] = (-0.5 * d).exp();
            }
        }
        let nrm = vec_norm(&col).max(1e-12);
        for (i, v) in col.iter().enumerate() {
            parts.set(i, j, v / nrm);
        }
    }

    // Images: nonnegative mixtures + global illumination + noise.
    let mut x = Mat::zeros(p, spec.n_images);
    for img in 0..spec.n_images {
        // Sparse-ish nonneg weights: each part present with prob 0.8.
        let mut weights = vec![0.0f64; k];
        for w in weights.iter_mut() {
            if rng.uniform() < 0.8 {
                *w = 0.3 + rng.uniform();
            }
        }
        let illum = 0.5 + rng.uniform(); // per-image lighting scale
        for j in 0..k {
            let wj = weights[j] * illum;
            if wj > 0.0 {
                for i in 0..p {
                    let v = x.get(i, img) + wj * parts.get(i, j);
                    x.set(i, img, v);
                }
            }
        }
        for i in 0..p {
            let v = x.get(i, img) + spec.noise * rng.uniform();
            x.set(i, img, v);
        }
    }

    FacesData { x, parts, spec: spec.clone() }
}

/// Greedy best-match cosine similarity between learned basis columns and
/// ground-truth parts, averaged — the "did NMF find the parts?" score used
/// by `bench_fig04_faces_basis`. 1.0 = perfect recovery.
pub fn part_recovery_score(learned_w: &Mat, true_parts: &Mat) -> f64 {
    let k_learn = learned_w.cols();
    let k_true = true_parts.cols();
    if k_learn == 0 || k_true == 0 {
        return 0.0;
    }
    let mut used = vec![false; k_learn];
    let mut total = 0.0;
    for tj in 0..k_true {
        let t = true_parts.col(tj);
        let tn = vec_norm(&t).max(1e-12);
        let mut best = 0.0;
        let mut best_i = None;
        for lj in 0..k_learn {
            if used[lj] {
                continue;
            }
            let l = learned_w.col(lj);
            let ln = vec_norm(&l).max(1e-12);
            let dot: f64 = t.iter().zip(l.iter()).map(|(a, b)| a * b).sum();
            let cos = dot / (tn * ln);
            if cos > best {
                best = cos;
                best_i = Some(lj);
            }
        }
        if let Some(i) = best_i {
            used[i] = true;
        }
        total += best;
    }
    total / k_true as f64
}

/// Render one basis column as an ASCII-art PGM (P2) image string —
/// the bench targets dump these so basis images are inspectable without
/// plotting infrastructure.
pub fn to_pgm(column: &[f64], height: usize, width: usize) -> String {
    assert_eq!(column.len(), height * width);
    let max = column.iter().copied().fold(0.0f64, f64::max).max(1e-12);
    let mut s = format!("P2\n{width} {height}\n255\n");
    for r in 0..height {
        let row: Vec<String> = (0..width)
            .map(|c| format!("{}", (column[r * width + c] / max * 255.0) as u8))
            .collect();
        s.push_str(&row.join(" "));
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_nonnegativity() {
        let spec =
            FacesSpec { height: 12, width: 10, n_images: 20, n_parts: 6, noise: 0.01, seed: 1 };
        let d = generate(&spec);
        assert_eq!(d.x.shape(), (120, 20));
        assert_eq!(d.parts.shape(), (120, 6));
        assert!(d.x.is_nonneg());
        assert!(d.parts.is_nonneg());
        assert!(d.x.sum() > 0.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = FacesSpec { height: 8, width: 8, n_images: 5, n_parts: 4, noise: 0.01, seed: 7 };
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a.x, b.x);
        let spec2 = FacesSpec { seed: 8, ..spec };
        assert_ne!(generate(&spec2).x, a.x);
    }

    #[test]
    fn effective_rank_close_to_parts() {
        // Spectrum should drop sharply after n_parts (+1 for illumination).
        let spec =
            FacesSpec { height: 16, width: 14, n_images: 60, n_parts: 6, noise: 0.001, seed: 2 };
        let d = generate(&spec);
        let svd = crate::linalg::svd::jacobi_svd(&d.x.transpose());
        let head: f64 = svd.s[..6].iter().map(|s| s * s).sum();
        let tail: f64 = svd.s[6..].iter().map(|s| s * s).sum();
        assert!(head / (head + tail) > 0.95, "energy in head = {}", head / (head + tail));
    }

    #[test]
    fn perfect_recovery_scores_one() {
        let spec =
            FacesSpec { height: 10, width: 10, n_images: 5, n_parts: 5, noise: 0.0, seed: 3 };
        let d = generate(&spec);
        let score = part_recovery_score(&d.parts, &d.parts);
        assert!((score - 1.0).abs() < 1e-9);
        // Random basis scores much lower.
        let mut rng = Pcg64::seed_from_u64(4);
        let random = rng.uniform_mat(100, 5);
        assert!(part_recovery_score(&random, &d.parts) < 0.9);
    }

    #[test]
    fn nmf_recovers_parts_better_than_random_basis() {
        let spec =
            FacesSpec { height: 16, width: 14, n_images: 80, n_parts: 5, noise: 0.01, seed: 5 };
        let d = generate(&spec);
        let fit = crate::nmf::hals::Hals::new(
            crate::nmf::options::NmfOptions::new(5).with_max_iter(200).with_seed(6),
        )
        .fit(&d.x)
        .unwrap();
        let score = part_recovery_score(&fit.model.w, &d.parts);
        assert!(score > 0.7, "NMF should find the parts: score={score}");
    }

    #[test]
    fn pgm_format() {
        let img = to_pgm(&[0.0, 1.0, 0.5, 0.25], 2, 2);
        assert!(img.starts_with("P2\n2 2\n255\n"));
        assert!(img.contains("255"));
    }
}

//! I/O robustness primitives shared by the storage and persistence layers.
//!
//! Three pieces:
//!
//! * **CRC32** (IEEE 802.3, table-driven) — integrity checksums for
//!   `.nmfstore` slabs and `.nmfckpt` checkpoints.
//! * **Fault taxonomy** — [`StoreFault`] tags every I/O error as
//!   [`Corrupt`](FaultKind::Corrupt) (data failed validation; retrying the
//!   same bytes is pointless beyond one re-read), [`Transient`]
//!   (FaultKind::Transient) (interrupted syscall, injected flake; worth a
//!   bounded retry) or [`Fatal`](FaultKind::Fatal) (missing file,
//!   permission, logic error). The vendored `anyhow` shim is string-backed
//!   (no `downcast_ref`), so the kind travels as a stable `[fault:…]`
//!   marker in the message and [`classify`] recovers it at any wrap depth.
//! * **Hardened syscall wrappers** — [`pread_exact`] survives EINTR and
//!   short reads; [`with_retry`] drives a bounded retry-with-backoff
//!   policy keyed on the fault kind. Both double as the injection points
//!   for the deterministic failpoints
//!   ([`crate::testing::failpoints`], `--features failpoints` only).

use std::fs::File;
use std::io;
use std::os::unix::fs::FileExt;

// ---------------------------------------------------------------------------
// CRC32 (IEEE reflected polynomial 0xEDB88320)
// ---------------------------------------------------------------------------

const fn make_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut j = 0;
        while j < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            j += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = make_crc_table();

/// CRC32 of `bytes` (IEEE; matches zlib's `crc32(0, …)`).
pub fn crc32(bytes: &[u8]) -> u32 {
    crc32_update(0, bytes)
}

/// Streaming form: `crc32_update(crc32(a), b) == crc32(a ‖ b)`.
pub fn crc32_update(seed: u32, bytes: &[u8]) -> u32 {
    let mut c = !seed;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

// ---------------------------------------------------------------------------
// Fault taxonomy
// ---------------------------------------------------------------------------

/// How an I/O failure should be treated by callers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Data read back but failed validation (CRC, magic, bounds). One
    /// re-read is worth trying (in-flight flip); after that, give up —
    /// the bytes on disk are wrong and must never be consumed.
    Corrupt,
    /// The operation itself flaked (EINTR, timeout, injected flake) —
    /// retry with backoff, bounded.
    Transient,
    /// Unrecoverable (missing file, permissions, caller bug).
    Fatal,
}

impl FaultKind {
    /// Stable substring embedded in error messages; [`classify`] parses it
    /// back out at any context-wrap depth.
    pub fn marker(self) -> &'static str {
        match self {
            FaultKind::Corrupt => "[fault:corrupt]",
            FaultKind::Transient => "[fault:transient]",
            FaultKind::Fatal => "[fault:fatal]",
        }
    }
}

/// Typed storage fault. Converts into `anyhow::Error` via the std-error
/// blanket impl; the kind survives as the Display marker.
#[derive(Debug)]
pub struct StoreFault {
    pub kind: FaultKind,
    pub detail: String,
}

impl std::fmt::Display for StoreFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {}", self.kind.marker(), self.detail)
    }
}

impl std::error::Error for StoreFault {}

/// Shorthand constructors (each returns a ready-to-`?` `anyhow::Error`).
pub fn corrupt(detail: impl Into<String>) -> anyhow::Error {
    StoreFault { kind: FaultKind::Corrupt, detail: detail.into() }.into()
}

pub fn transient(detail: impl Into<String>) -> anyhow::Error {
    StoreFault { kind: FaultKind::Transient, detail: detail.into() }.into()
}

pub fn fatal(detail: impl Into<String>) -> anyhow::Error {
    StoreFault { kind: FaultKind::Fatal, detail: detail.into() }.into()
}

/// Recover the fault kind from an (arbitrarily context-wrapped) error.
/// Unmarked errors are conservatively [`FaultKind::Fatal`] — never retried.
pub fn classify(err: &anyhow::Error) -> FaultKind {
    let s = err.to_string();
    if s.contains(FaultKind::Corrupt.marker()) {
        FaultKind::Corrupt
    } else if s.contains(FaultKind::Transient.marker()) {
        FaultKind::Transient
    } else {
        FaultKind::Fatal
    }
}

/// Wrap a raw `io::Error` from operation `op` into a classified fault.
pub fn io_fault(op: &str, err: io::Error) -> anyhow::Error {
    use io::ErrorKind as K;
    let msg = err.to_string();
    let kind = if msg.contains(FaultKind::Transient.marker())
        || matches!(err.kind(), K::Interrupted | K::WouldBlock | K::TimedOut)
    {
        FaultKind::Transient
    } else if matches!(err.kind(), K::UnexpectedEof | K::InvalidData) {
        FaultKind::Corrupt
    } else {
        FaultKind::Fatal
    };
    StoreFault { kind, detail: format!("{op}: {msg}") }.into()
}

// ---------------------------------------------------------------------------
// Hardened syscalls
// ---------------------------------------------------------------------------

fn eof(offset: u64, missing: usize) -> io::Error {
    io::Error::new(
        io::ErrorKind::UnexpectedEof,
        format!("pread at offset {offset}: file ended {missing} bytes early"),
    )
}

/// Positional read of exactly `buf.len()` bytes at `offset`, resuming
/// across EINTR and short reads. Under `--features failpoints` this is
/// the injection point for short reads, EINTR, transient errors and
/// bit corruption.
pub fn pread_exact(file: &File, buf: &mut [u8], offset: u64) -> io::Result<()> {
    let mut done = 0usize;
    while done < buf.len() {
        let at = offset + done as u64;
        #[cfg(feature = "failpoints")]
        {
            use crate::testing::failpoints as fp;
            match fp::read_fault(buf.len() - done) {
                Some(fp::ReadFault::Eintr) => continue, // interrupted before any bytes
                Some(fp::ReadFault::Transient) => {
                    return Err(io::Error::other(
                        "[fault:transient] injected transient read error",
                    ));
                }
                Some(fp::ReadFault::Short(cap)) => {
                    let want = cap.clamp(1, buf.len() - done);
                    match file.read_at(&mut buf[done..done + want], at) {
                        Ok(0) => return Err(eof(at, buf.len() - done)),
                        Ok(n) => done += n,
                        Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                        Err(e) => return Err(e),
                    }
                    continue;
                }
                Some(fp::ReadFault::CorruptBit { pos, mask }) => {
                    match file.read_at(&mut buf[done..], at) {
                        Ok(0) => return Err(eof(at, buf.len() - done)),
                        Ok(n) => {
                            buf[done + pos % n] ^= mask;
                            done += n;
                        }
                        Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                        Err(e) => return Err(e),
                    }
                    continue;
                }
                None => {}
            }
        }
        match file.read_at(&mut buf[done..], at) {
            Ok(0) => return Err(eof(at, buf.len() - done)),
            Ok(n) => done += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Positional write of all of `buf` at `offset` (EINTR handled by
/// `write_all_at`); failpoint injection site for write flakes.
pub fn pwrite_all(file: &File, buf: &[u8], offset: u64) -> io::Result<()> {
    #[cfg(feature = "failpoints")]
    if crate::testing::failpoints::write_fault() {
        return Err(io::Error::other("[fault:transient] injected transient write error"));
    }
    file.write_all_at(buf, offset)
}

/// Retry attempts granted to transient faults (beyond the first try).
pub const TRANSIENT_RETRIES: u32 = 3;

/// Run `f` under the bounded retry policy: transient faults get
/// [`TRANSIENT_RETRIES`] retries with exponential backoff, a corrupt
/// result gets exactly one re-read (covers in-flight bit flips), fatal
/// errors propagate immediately. The final error keeps its fault marker
/// so callers can still [`classify`] it.
pub fn with_retry<T>(what: &str, mut f: impl FnMut() -> anyhow::Result<T>) -> anyhow::Result<T> {
    let mut transient_used = 0u32;
    let mut corrupt_used = 0u32;
    loop {
        match f() {
            Ok(v) => return Ok(v),
            Err(e) => match classify(&e) {
                FaultKind::Transient if transient_used < TRANSIENT_RETRIES => {
                    transient_used += 1;
                    std::thread::sleep(std::time::Duration::from_micros(50u64 << transient_used));
                }
                FaultKind::Corrupt if corrupt_used < 1 => corrupt_used += 1,
                _ => {
                    return Err(anyhow::anyhow!(
                        "{what}: giving up after {transient_used} transient / \
                         {corrupt_used} corrupt retries: {e}"
                    ));
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // Canonical IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // Streaming composition matches one-shot.
        let a = b"hello ";
        let b = b"world";
        let mut joined = a.to_vec();
        joined.extend_from_slice(b);
        assert_eq!(crc32_update(crc32(a), b), crc32(&joined));
    }

    #[test]
    fn crc32_detects_single_bit_flips() {
        let mut data = vec![0u8; 4096];
        for (i, v) in data.iter_mut().enumerate() {
            *v = (i * 7) as u8;
        }
        let base = crc32(&data);
        for &(pos, bit) in &[(0usize, 0u8), (17, 3), (4095, 7)] {
            let mut flipped = data.clone();
            flipped[pos] ^= 1 << bit;
            assert_ne!(crc32(&flipped), base, "flip at byte {pos} bit {bit} undetected");
        }
    }

    #[test]
    fn classify_survives_context_wrapping() {
        use anyhow::Context;
        let e = corrupt("slab 3 checksum mismatch");
        assert_eq!(classify(&e), FaultKind::Corrupt);
        let wrapped: anyhow::Error =
            Err::<(), _>(e).context("reading block 3").context("fit sweep 12").unwrap_err();
        assert_eq!(classify(&wrapped), FaultKind::Corrupt);
        assert_eq!(classify(&transient("flake")), FaultKind::Transient);
        assert_eq!(classify(&fatal("gone")), FaultKind::Fatal);
        assert_eq!(classify(&anyhow::anyhow!("unmarked")), FaultKind::Fatal);
    }

    #[test]
    fn io_fault_maps_kinds() {
        let i = io::Error::new(io::ErrorKind::Interrupted, "EINTR");
        assert_eq!(classify(&io_fault("pread", i)), FaultKind::Transient);
        let t = io::Error::new(io::ErrorKind::UnexpectedEof, "short file");
        assert_eq!(classify(&io_fault("pread", t)), FaultKind::Corrupt);
        let f = io::Error::new(io::ErrorKind::NotFound, "gone");
        assert_eq!(classify(&io_fault("open", f)), FaultKind::Fatal);
    }

    #[test]
    fn with_retry_policies() {
        // Transient: succeeds within the budget.
        let mut left = 2;
        let got = with_retry("flaky", || {
            if left > 0 {
                left -= 1;
                Err(transient("flake"))
            } else {
                Ok(42)
            }
        })
        .unwrap();
        assert_eq!(got, 42);

        // Transient: budget exhausted -> error keeps the marker.
        let mut calls = 0u32;
        let err = with_retry("always-flaky", || -> anyhow::Result<()> {
            calls += 1;
            Err(transient("flake"))
        })
        .unwrap_err();
        assert_eq!(calls, 1 + TRANSIENT_RETRIES);
        assert_eq!(classify(&err), FaultKind::Transient);

        // Corrupt: exactly one re-read.
        let mut calls = 0u32;
        let err = with_retry("bad-disk", || -> anyhow::Result<()> {
            calls += 1;
            Err(corrupt("crc mismatch"))
        })
        .unwrap_err();
        assert_eq!(calls, 2);
        assert_eq!(classify(&err), FaultKind::Corrupt);

        // Fatal: no retry.
        let mut calls = 0u32;
        let err = with_retry("missing", || -> anyhow::Result<()> {
            calls += 1;
            Err(fatal("no such file"))
        })
        .unwrap_err();
        assert_eq!(calls, 1);
        assert_eq!(classify(&err), FaultKind::Fatal);
    }

    #[test]
    fn pread_exact_reads_across_offsets() {
        let dir = std::env::temp_dir().join("randnmf_robust_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pread.bin");
        let data: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
        std::fs::write(&path, &data).unwrap();
        let f = File::open(&path).unwrap();
        let mut buf = vec![0u8; 400];
        pread_exact(&f, &mut buf, 300).unwrap();
        assert_eq!(&buf[..], &data[300..700]);
        // Reading past EOF is an UnexpectedEof, not a hang or partial Ok.
        let mut big = vec![0u8; 200];
        let err = pread_exact(&f, &mut big, 900).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        std::fs::remove_file(&path).ok();
    }
}

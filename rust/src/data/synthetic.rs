//! Synthetic nonnegative low-rank data (paper §4.4).
//!
//! The paper's computational benchmarks use "low-rank matrices consisting
//! of nonnegative elements drawn from the Gaussian distribution": exact
//! rank-`r` products of nonnegative factors. Three named shapes appear:
//!
//! * tall-and-skinny `100,000 × 5,000` (Fig. 11a),
//! * fat `25,000 × 25,000` (Fig. 11b),
//! * square `5,000 × 5,000` (Figs. 12–13),
//!
//! all of rank 40. The helpers below reproduce those (and scaled-down
//! variants for CI-speed runs).

use crate::linalg::gemm;
use crate::linalg::mat::Mat;
use crate::linalg::rng::Pcg64;
use crate::linalg::sparse::CsrMat;

/// Nonnegative matrix of exact rank `r`: `X = U·V` with `U, V ≥ 0` drawn
/// as `|N(0,1)|`, plus optional nonnegative noise of relative magnitude
/// `noise`.
pub fn low_rank_nonneg(m: usize, n: usize, r: usize, noise: f64, rng: &mut Pcg64) -> Mat {
    let u = rng.gaussian_mat(m, r).map(f64::abs);
    let v = rng.gaussian_mat(r, n).map(f64::abs);
    let mut x = gemm::matmul(&u, &v);
    if noise > 0.0 {
        let scale = noise * x.sum() / x.len() as f64;
        for val in x.as_mut_slice() {
            *val += scale * rng.uniform();
        }
    }
    x
}

/// Fig. 11a workload (optionally scaled by `scale ∈ (0, 1]`).
pub fn tall_and_skinny(scale: f64, rng: &mut Pcg64) -> Mat {
    let m = ((100_000.0 * scale) as usize).max(64);
    let n = ((5_000.0 * scale) as usize).max(32);
    low_rank_nonneg(m, n, 40.min(n / 2).max(2), 0.0, rng)
}

/// Fig. 11b workload.
pub fn fat(scale: f64, rng: &mut Pcg64) -> Mat {
    let s = ((25_000.0 * scale) as usize).max(64);
    low_rank_nonneg(s, s, 40.min(s / 2).max(2), 0.0, rng)
}

/// Figs. 12–13 workload.
pub fn square(scale: f64, rng: &mut Pcg64) -> Mat {
    let s = ((5_000.0 * scale) as usize).max(64);
    low_rank_nonneg(s, s, 40.min(s / 2).max(2), 0.0, rng)
}

/// Sparse nonnegative "topics" matrix in CSR form: a rank-`r`
/// nonnegative product `U·V` sampled on a random support of the given
/// `density` (per-row `round(density·n)` distinct columns) — the
/// bag-of-words / recommender regime the sparse rHALS pipeline targets.
///
/// Built directly as triplets; the dense `m×n` matrix is **never
/// materialized**, so paper-scale shapes at 1% density fit comfortably
/// in memory. Note the support mask makes the matrix only
/// approximately low-rank (a masked low-rank product), which is exactly
/// the hard-but-realistic case for the sketch; use
/// [`CsrMat::to_dense`] when an exact densified copy is needed (the
/// sparse-vs-dense equivalence property test does).
pub fn sparse_low_rank(m: usize, n: usize, r: usize, density: f64, rng: &mut Pcg64) -> CsrMat {
    assert!(m > 0 && n > 0 && r > 0, "sparse_low_rank: empty shape");
    let density = density.clamp(0.0, 1.0);
    let u = rng.gaussian_mat(m, r).map(f64::abs);
    let v = rng.gaussian_mat(r, n).map(f64::abs);
    let per_row = ((density * n as f64).round() as usize).min(n);
    let mut triplets = Vec::with_capacity(m * per_row);
    // Per-row rejection table: mark[j] == i means column j is already
    // drawn for row i (no clearing between rows needed).
    let mut mark = vec![usize::MAX; n];
    for i in 0..m {
        let mut drawn = 0;
        while drawn < per_row {
            let j = rng.uniform_usize(n);
            if mark[j] == i {
                continue;
            }
            mark[j] = i;
            drawn += 1;
            let mut val = 0.0;
            for t in 0..r {
                val += u.get(i, t) * v.get(t, j);
            }
            triplets.push((i, j, val));
        }
    }
    CsrMat::from_triplets(m, n, &triplets)
}

/// Matrix with a slowly decaying singular spectrum (`σ_i ∝ i^{-decay}`)
/// and nonnegative entries — the hard case for sketching without power
/// iterations, used by the `q` ablation bench.
pub fn slow_spectrum(m: usize, n: usize, decay: f64, rng: &mut Pcg64) -> Mat {
    let r = m.min(n);
    let u = crate::linalg::qr::orthonormalize(&rng.gaussian_mat(m, r));
    let v = crate::linalg::qr::orthonormalize(&rng.gaussian_mat(n, r));
    let mut us = u;
    for j in 0..r {
        let s = ((j + 1) as f64).powf(-decay);
        for i in 0..m {
            let val = us.get(i, j) * s;
            us.set(i, j, val);
        }
    }
    let mut x = gemm::a_bt(&us, &v);
    // Shift to nonnegativity (preserves the spectrum's decay profile up to
    // one rank-1 component).
    let min = x.min();
    if min < 0.0 {
        x.map_inplace(|v| v - min);
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::jacobi_svd;

    #[test]
    fn exact_rank() {
        let mut rng = Pcg64::seed_from_u64(1);
        let x = low_rank_nonneg(50, 40, 7, 0.0, &mut rng);
        assert!(x.is_nonneg());
        let svd = jacobi_svd(&x);
        for i in 7..svd.s.len() {
            assert!(svd.s[i] < 1e-8 * svd.s[0], "rank should be exactly 7");
        }
    }

    #[test]
    fn noise_raises_rank() {
        let mut rng = Pcg64::seed_from_u64(2);
        let x = low_rank_nonneg(40, 30, 4, 0.05, &mut rng);
        let svd = jacobi_svd(&x);
        assert!(svd.s[10] > 1e-8 * svd.s[0], "noise should fill the spectrum");
        assert!(x.is_nonneg());
    }

    #[test]
    fn named_workload_shapes() {
        let mut rng = Pcg64::seed_from_u64(3);
        let t = tall_and_skinny(0.01, &mut rng);
        assert_eq!(t.shape(), (1000, 50));
        let f = fat(0.005, &mut rng);
        assert_eq!(f.shape(), (125, 125));
        let s = square(0.02, &mut rng);
        assert_eq!(s.shape(), (100, 100));
    }

    #[test]
    fn sparse_low_rank_density_and_nonneg() {
        let mut rng = Pcg64::seed_from_u64(5);
        let x = sparse_low_rank(200, 80, 5, 0.05, &mut rng);
        assert_eq!(x.shape(), (200, 80));
        assert!(x.is_nonneg());
        // Exactly round(0.05·80) = 4 distinct columns per row.
        assert_eq!(x.nnz(), 200 * 4);
        assert!((x.density() - 0.05).abs() < 1e-12);
        for i in 0..200 {
            let (js, _) = x.row(i);
            assert_eq!(js.len(), 4);
            for w in js.windows(2) {
                assert!(w[0] < w[1], "row {i}: columns not strictly ascending");
            }
        }
        // A zero density is a valid (empty) matrix.
        let mut rng = Pcg64::seed_from_u64(6);
        let empty = sparse_low_rank(10, 10, 2, 0.0, &mut rng);
        assert_eq!(empty.nnz(), 0);
    }

    #[test]
    fn slow_spectrum_decays_slowly() {
        let mut rng = Pcg64::seed_from_u64(4);
        let x = slow_spectrum(60, 60, 0.5, &mut rng);
        assert!(x.is_nonneg());
        let svd = jacobi_svd(&x);
        // σ_20 / σ_2 should still be substantial (slow decay).
        assert!(svd.s[20] / svd.s[2] > 0.2, "ratio {}", svd.s[20] / svd.s[2]);
    }
}

//! Synthetic stroke-digit dataset — substitute for MNIST (paper §4.3,
//! Tables 3–4, Fig. 10; see DESIGN.md §5).
//!
//! The MNIST experiment asks whether randomized-NMF features classify as
//! well as deterministic-NMF features under kNN. What that needs from the
//! data is (a) nonnegative images, (b) class structure, (c) parts-based
//! composition (strokes). Each digit class here is a fixed set of line
//! segments on a 28×28 grid; samples jitter the segment endpoints,
//! thickness and intensity and add sensor noise, then images are rendered
//! with an anti-aliased distance field.

use crate::linalg::mat::Mat;
use crate::linalg::rng::Pcg64;

/// Image side (MNIST-compatible 28).
pub const SIDE: usize = 28;
/// Pixels per image.
pub const PIXELS: usize = SIDE * SIDE;

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct DigitsSpec {
    pub n_train: usize,
    pub n_test: usize,
    pub noise: f64,
    pub seed: u64,
}

impl DigitsSpec {
    /// Paper-scale: 60,000 train + 10,000 test.
    pub fn paper() -> Self {
        DigitsSpec { n_train: 60_000, n_test: 10_000, noise: 0.02, seed: 42 }
    }

    pub fn small() -> Self {
        DigitsSpec { n_train: 600, n_test: 200, noise: 0.02, seed: 42 }
    }
}

/// Generated dataset (column-major samples like the rest of the crate:
/// `x` is pixels × samples).
pub struct DigitsData {
    pub train_x: Mat,
    pub train_y: Vec<u8>,
    pub test_x: Mat,
    pub test_y: Vec<u8>,
}

/// Segment strokes per class in unit coordinates `(y0, x0, y1, x1)`.
/// Hand-designed seven-segment-like glyphs for digits 0–9.
fn class_strokes(digit: u8) -> &'static [(f64, f64, f64, f64)] {
    const T: (f64, f64, f64, f64) = (0.15, 0.25, 0.15, 0.75); // top
    const M: (f64, f64, f64, f64) = (0.50, 0.25, 0.50, 0.75); // middle
    const B: (f64, f64, f64, f64) = (0.85, 0.25, 0.85, 0.75); // bottom
    const TL: (f64, f64, f64, f64) = (0.15, 0.25, 0.50, 0.25); // top-left
    const TR: (f64, f64, f64, f64) = (0.15, 0.75, 0.50, 0.75); // top-right
    const BL: (f64, f64, f64, f64) = (0.50, 0.25, 0.85, 0.25); // bottom-left
    const BR: (f64, f64, f64, f64) = (0.50, 0.75, 0.85, 0.75); // bottom-right
    match digit {
        0 => &[T, TL, TR, BL, BR, B],
        1 => &[TR, BR],
        2 => &[T, TR, M, BL, B],
        3 => &[T, TR, M, BR, B],
        4 => &[TL, TR, M, BR],
        5 => &[T, TL, M, BR, B],
        6 => &[T, TL, M, BL, BR, B],
        7 => &[T, TR, BR],
        8 => &[T, TL, TR, M, BL, BR, B],
        9 => &[T, TL, TR, M, BR, B],
        _ => panic!("digit out of range"),
    }
}

/// Render one jittered digit into a pixel column.
fn render_digit(digit: u8, rng: &mut Pcg64, noise: f64, out: &mut [f64]) {
    let strokes = class_strokes(digit);
    // Jitter magnitudes are tuned so raw-pixel 3-NN reaches ~95% accuracy,
    // matching MNIST's difficulty for the Table 4 experiment.
    let jy = 0.02 * rng.gaussian();
    let jx = 0.02 * rng.gaussian();
    let scale = 0.95 + 0.1 * rng.uniform();
    let thickness = 0.045 + 0.02 * rng.uniform();
    let intensity = 0.8 + 0.2 * rng.uniform();
    out.fill(0.0);
    for &(y0, x0, y1, x1) in strokes {
        // per-stroke endpoint jitter
        let (y0, x0, y1, x1) = (
            0.5 + (y0 - 0.5) * scale + jy + 0.005 * rng.gaussian(),
            0.5 + (x0 - 0.5) * scale + jx + 0.005 * rng.gaussian(),
            0.5 + (y1 - 0.5) * scale + jy + 0.005 * rng.gaussian(),
            0.5 + (x1 - 0.5) * scale + jx + 0.005 * rng.gaussian(),
        );
        for py in 0..SIDE {
            let y = (py as f64 + 0.5) / SIDE as f64;
            for px in 0..SIDE {
                let x = (px as f64 + 0.5) / SIDE as f64;
                let d = dist_to_segment(y, x, y0, x0, y1, x1);
                // Anti-aliased falloff around the stroke core.
                let v = intensity * (1.0 - (d / thickness).powi(2)).max(0.0);
                let idx = py * SIDE + px;
                out[idx] = out[idx].max(v);
            }
        }
    }
    for v in out.iter_mut() {
        *v = (*v + noise * rng.uniform()).min(1.0);
    }
}

fn dist_to_segment(py: f64, px: f64, y0: f64, x0: f64, y1: f64, x1: f64) -> f64 {
    let (dy, dx) = (y1 - y0, x1 - x0);
    let len_sq = dy * dy + dx * dx;
    let t = if len_sq == 0.0 {
        0.0
    } else {
        (((py - y0) * dy + (px - x0) * dx) / len_sq).clamp(0.0, 1.0)
    };
    let (cy, cx) = (y0 + t * dy, x0 + t * dx);
    ((py - cy).powi(2) + (px - cx).powi(2)).sqrt()
}

/// Generate train and test splits (balanced classes, shuffled order).
pub fn generate(spec: &DigitsSpec) -> DigitsData {
    let mut rng = Pcg64::seed_from_u64(spec.seed);
    let make = |n: usize, rng: &mut Pcg64| -> (Mat, Vec<u8>) {
        let mut x = Mat::zeros(PIXELS, n);
        let mut y = Vec::with_capacity(n);
        let mut buf = vec![0.0f64; PIXELS];
        for i in 0..n {
            let digit = (i % 10) as u8;
            render_digit(digit, rng, spec.noise, &mut buf);
            x.set_col(i, &buf);
            y.push(digit);
        }
        // Shuffle columns so class order carries no signal.
        let mut perm: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut perm);
        let mut xs = Mat::zeros(PIXELS, n);
        let mut ys = vec![0u8; n];
        for (new, &old) in perm.iter().enumerate() {
            xs.set_col(new, &x.col(old));
            ys[new] = y[old];
        }
        (xs, ys)
    };
    let (train_x, train_y) = make(spec.n_train, &mut rng);
    let (test_x, test_y) = make(spec.n_test, &mut rng);
    DigitsData { train_x, train_y, test_x, test_y }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_labels_nonneg() {
        let d = generate(&DigitsSpec { n_train: 50, n_test: 20, noise: 0.02, seed: 1 });
        assert_eq!(d.train_x.shape(), (PIXELS, 50));
        assert_eq!(d.test_x.shape(), (PIXELS, 20));
        assert_eq!(d.train_y.len(), 50);
        assert!(d.train_x.is_nonneg());
        assert!(d.train_x.max() <= 1.0);
        assert!(d.train_y.iter().all(|&y| y < 10));
        // Balanced-ish classes.
        for c in 0..10u8 {
            assert_eq!(d.train_y.iter().filter(|&&y| y == c).count(), 5);
        }
    }

    #[test]
    fn deterministic() {
        let spec = DigitsSpec { n_train: 20, n_test: 10, noise: 0.02, seed: 2 };
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.test_y, b.test_y);
    }

    #[test]
    fn classes_are_distinguishable() {
        // Same-class samples must be closer (on average) than cross-class.
        let d = generate(&DigitsSpec { n_train: 100, n_test: 0, noise: 0.02, seed: 3 });
        let dist = |a: usize, b: usize| -> f64 {
            d.train_x
                .col(a)
                .iter()
                .zip(d.train_x.col(b).iter())
                .map(|(x, y)| (x - y).powi(2))
                .sum::<f64>()
        };
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for a in 0..40 {
            for b in (a + 1)..40 {
                if d.train_y[a] == d.train_y[b] {
                    same.push(dist(a, b));
                } else {
                    diff.push(dist(a, b));
                }
            }
        }
        let ms = crate::coordinator::metrics::mean(&same);
        let md = crate::coordinator::metrics::mean(&diff);
        // Jittered strokes overlap across classes (7-segment glyphs share
        // segments), so require a clear but not extreme separation.
        assert!(ms < md * 0.85, "same-class {ms} vs cross-class {md}");
    }

    #[test]
    fn strokes_defined_for_all_digits() {
        for d in 0..10u8 {
            assert!(!class_strokes(d).is_empty());
        }
    }
}

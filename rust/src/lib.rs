//! # randnmf — Randomized Nonnegative Matrix Factorization
//!
//! A production-grade reproduction of *"Randomized Nonnegative Matrix
//! Factorization"* (Erichson, Mendible, Wihlborn, Kutz; stat.ML 2017,
//! Pattern Recognition Letters 2018).
//!
//! The crate is organized as a three-layer system:
//!
//! * **L3 (this crate)** — the coordinator: dataset store, config/CLI,
//!   sweep scheduler, metrics, evaluation, and the full family of NMF
//!   algorithms (deterministic HALS, randomized HALS, MU, compressed MU,
//!   regularized variants) on top of an in-repo dense linear-algebra
//!   substrate ([`linalg`]) and the randomized QB range finder ([`sketch`]).
//! * **L2 (JAX, build time)** — `python/compile/model.py` lowers the HALS
//!   iteration and QB sketch to HLO text artifacts.
//! * **L1 (Pallas, build time)** — `python/compile/kernels/` author the
//!   coordinate-sweep and tiled-matmul kernels called by L2.
//!
//! At runtime the [`runtime`] module loads the AOT artifacts through PJRT
//! and exposes them behind the same engine trait as the pure-Rust path, so
//! Python is never on the request path.
//!
//! ## Performance architecture
//!
//! Two rules hold on every hot path:
//!
//! * **Workspace discipline** ([`linalg::workspace`]) — every GEMM has an
//!   `_into` variant writing into caller-owned outputs with pooled
//!   scratch; solver loops allocate everything before iterating, so
//!   steady-state iterations perform zero heap allocations (enforced by
//!   `tests/test_zero_alloc.rs` and `tests/test_zero_alloc_pool.rs`).
//!   The randomized solvers go further: `RandomizedHals::fit_with` /
//!   `CompressedMu::fit_with` run the *entire* fit — QB compression
//!   stage included, via [`sketch::qb::qb_into`] and the Gram-based
//!   CholeskyQR2 of [`linalg::qr::orthonormalize_into`] — out of one
//!   reusable scratch, so a warm fit allocates nothing at all.
//! * **Persistent worker pool** ([`linalg::pool`]) — threaded kernels
//!   never spawn threads per call: workers are spawned once (sized by
//!   `RANDNMF_THREADS`), parked between calls, and fed pre-partitioned
//!   ranges through lock-free job cells. The packed BLIS-style GEMM
//!   engine ([`linalg::gemm`]) rides on both, with triangle-aware Gram
//!   kernels computing only the upper triangle of `WᵀW`/`HHᵀ`, and the
//!   compression stage (dense or sparse-sign sketches, power iterations,
//!   `B = QᵀX`) dispatches its large products onto the same pool.
//!
//! The compression layer offers four test-matrix families
//! ([`sketch::qb::SketchKind`]: uniform, gaussian, sparse-sign, and the
//! SRHT fast sketch of [`sketch::srht`]) and two compression topologies
//! (one-sided QB, and the two-sided row+column compression of
//! [`sketch::twosided`] consumed by [`nmf::twosided::TwoSidedHals`]).
//! The full decision table — cost models, determinism guarantees, and
//! the workspace discipline a new sketch kind must follow — lives in
//! `docs/COMPRESSION.md`.
//!
//! Both hot-path rules — plus the `SAFETY` audit over the pool's
//! lifetime-erased dispatch, the dispatch-exhaustiveness tripwires over
//! [`sketch::qb::SketchKind`] / `SolverKind`, a call-graph closure that
//! makes zero-alloc transitive, per-binding acquire/release dataflow,
//! and determinism rules over the numeric tree — are machine-checked:
//! the `tools/randnmf-lint` workspace member lints the whole workspace
//! in CI (`cargo run -p randnmf-lint -- rust/src rust/tests
//! rust/benches tools`), and loom/Miri/TSan jobs check the pool mailbox
//! protocol ([`linalg::pool`]). Rules, annotation syntax, and the
//! soundness matrix live in `docs/STATIC_ANALYSIS.md`.
//!
//! Inputs may be dense ([`linalg::mat::Mat`]), sparse CSR
//! ([`linalg::sparse::CsrMat`]), or dual-storage sparse
//! ([`linalg::sparse::SparseMat`] — CSR plus a lazily built CSC mirror
//! whose transpose-side products run reduce-free): the sketch engine,
//! the deterministic `Hals`/`Mu` solvers, and `RandomizedHals::fit_with`
//! all accept any of them via [`linalg::sparse::NmfInput`], and on
//! sparse data every pass over `X` runs in `O(nnz·l)` without ever
//! materializing an `m×n` buffer — see `examples/sparse_topics.rs` for
//! the bag-of-words scenario. Out-of-core sparse data streams through
//! [`sketch::blocked::qb_blocked_sparse_with`] over the CSC-slab
//! [`data::store::SparseNmfStore`] at `O(nnz)` I/O per pass.
//!
//! ## Quickstart
//!
//! ```no_run
//! use randnmf::prelude::*;
//!
//! let mut rng = Pcg64::seed_from_u64(0);
//! let x = synthetic::low_rank_nonneg(2000, 500, 20, 0.0, &mut rng);
//! let opts = NmfOptions::new(16).with_max_iter(200).with_seed(7);
//! let fit = RandomizedHals::new(opts).fit(&x).unwrap();
//! println!("relative error = {}", fit.relative_error(&x));
//! ```

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod linalg;
pub mod nmf;
pub mod runtime;
pub mod sketch;
pub mod tensor;
pub mod testing;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::data::synthetic;
    pub use crate::linalg::mat::Mat;
    pub use crate::linalg::rng::Pcg64;
    pub use crate::linalg::sparse::{CscMat, CsrMat, NmfInput, SparseMat};
    pub use crate::linalg::workspace::Workspace;
    pub use crate::nmf::hals::{Hals, HalsScratch};
    pub use crate::nmf::model::{NmfFit, NmfModel};
    pub use crate::nmf::mu::{Mu, MuScratch};
    pub use crate::nmf::options::{Init, NmfOptions, Regularization, UpdateOrder};
    pub use crate::nmf::rhals::{RandomizedHals, RhalsScratch};
    pub use crate::nmf::twosided::{TwoSidedHals, TwoSidedScratch};
    pub use crate::sketch::qb::{qb, QbOptions, SketchKind};
    pub use crate::sketch::twosided::{two_sided, TwoSidedFactors};
}

//! Hostile-client robustness for the transform service edge.
//!
//! Raw-socket clients exercise the failure paths the friendly
//! `TransformClient` never hits: an absurd length prefix (must be
//! rejected *before* allocation, with an error reply and a closed
//! connection), a half-written request that stalls (must be dropped at
//! the read deadline without pinning a thread), a connection flood past
//! the bounded queue (must shed with explicit overload replies, never
//! grow memory), a combined storm of concurrent transforms racing both
//! hostile riders (served + shed accounting and the latency recorder
//! must stay exact), and a shutdown with requests in flight (must
//! drain — every accepted request gets its reply).
//!
//! After every attack, a healthy client on a fresh connection must still
//! be served: one hostile peer can never degrade the service for others.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Barrier;
use std::time::{Duration, Instant};

use randnmf::coordinator::server::{ServerOptions, TransformClient, TransformServer};
use randnmf::linalg::mat::Mat;
use randnmf::linalg::rng::Pcg64;
use randnmf::nmf::model::NmfModel;

const M: usize = 16;
const K: usize = 3;

fn test_model(seed: u64) -> NmfModel {
    let mut rng = Pcg64::seed_from_u64(seed);
    NmfModel { w: rng.uniform_mat(M, K).map(|v| v + 0.05), h: Mat::zeros(K, 1) }
}

/// Read one wire-format reply off a raw socket; `Err` is the server's
/// error message.
fn read_reply(s: &mut TcpStream) -> Result<Vec<f64>, String> {
    let mut hdr = [0u8; 4];
    s.read_exact(&mut hdr).expect("reply header");
    let k = u32::from_le_bytes(hdr);
    if k == u32::MAX {
        s.read_exact(&mut hdr).expect("error length");
        let mut msg = vec![0u8; u32::from_le_bytes(hdr) as usize];
        s.read_exact(&mut msg).expect("error body");
        return Err(String::from_utf8_lossy(&msg).into_owned());
    }
    let mut data = vec![0u8; k as usize * 8];
    s.read_exact(&mut data).expect("reply body");
    Ok(data.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
}

/// The service must answer a well-formed request on a fresh connection.
fn assert_healthy(addr: std::net::SocketAddr) {
    let mut client = TransformClient::connect(addr).unwrap();
    let code = client.transform(&vec![0.5; M]).unwrap();
    assert_eq!(code.len(), K);
    assert!(code.iter().all(|v| v.is_finite() && *v >= 0.0));
}

#[test]
fn oversized_length_prefix_gets_error_reply_then_close() {
    let server =
        TransformServer::start("127.0.0.1:0", test_model(1), ServerOptions::default()).unwrap();

    let mut s = TcpStream::connect(server.addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // Claim a gigantic request; never send the payload. The reply must
    // arrive before any payload-sized buffer could have been allocated.
    s.write_all(&(1u32 << 24).to_le_bytes()).unwrap();
    let err = read_reply(&mut s).unwrap_err();
    assert!(err.contains("exceeds server limit"), "{err}");

    // The connection is closed — the unread payload cannot be resynced.
    let mut probe = [0u8; 1];
    match s.read(&mut probe) {
        Ok(0) | Err(_) => {}
        Ok(n) => panic!("connection should be closed, read {n} more bytes"),
    }

    assert_healthy(server.addr());
    server.shutdown();
}

#[test]
fn stalled_half_written_request_is_dropped_at_deadline() {
    let opts = ServerOptions { read_timeout: Duration::from_millis(300), ..Default::default() };
    let server = TransformServer::start("127.0.0.1:0", test_model(2), opts).unwrap();

    let mut s = TcpStream::connect(server.addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // A valid prefix, then 5 of the 16 promised f64s — and silence.
    s.write_all(&(M as u32).to_le_bytes()).unwrap();
    s.write_all(&[0u8; 40]).unwrap();

    // The server must give up within the deadline (plus slack), closing
    // the connection rather than pinning its thread forever.
    let start = Instant::now();
    let mut probe = [0u8; 1];
    match s.read(&mut probe) {
        Ok(0) | Err(_) => {}
        Ok(n) => panic!("stalled connection should be dropped, read {n} bytes"),
    }
    assert!(
        start.elapsed() < Duration::from_secs(8),
        "drop took {:?} — stall deadline not enforced",
        start.elapsed()
    );

    assert_healthy(server.addr());
    server.shutdown();
}

#[test]
fn connection_flood_is_shed_with_bounded_queue() {
    let opts = ServerOptions {
        batch_window: Duration::from_millis(200),
        max_queue: 2,
        ..Default::default()
    };
    let server = TransformServer::start("127.0.0.1:0", test_model(3), opts).unwrap();
    let addr = server.addr();

    let nreq = 20;
    let barrier = Barrier::new(nreq);
    let mut served = 0u32;
    let mut shed = 0u32;
    std::thread::scope(|sc| {
        let handles: Vec<_> = (0..nreq)
            .map(|_| {
                let barrier = &barrier;
                sc.spawn(move || {
                    let mut client = TransformClient::connect(addr).unwrap();
                    barrier.wait(); // all requests hit the queue together
                    client.transform(&vec![0.5; M]).map_err(|e| e.to_string())
                })
            })
            .collect();
        for h in handles {
            // Every connection gets *some* reply: a code or an explicit
            // overload error — never a hang, never a dropped socket.
            match h.join().unwrap() {
                Ok(code) => {
                    assert_eq!(code.len(), K);
                    served += 1;
                }
                Err(e) => {
                    assert!(e.contains("overloaded"), "unexpected reply: {e}");
                    shed += 1;
                }
            }
        }
    });
    assert_eq!(served + shed, nreq as u32);
    assert!(served > 0, "flood starved every request");
    assert!(
        server.shed_count() > 0 && shed > 0,
        "queue bound never triggered (served {served}, shed {shed})"
    );

    assert_healthy(addr);
    server.shutdown();
}

#[test]
fn concurrent_transform_storm_keeps_accounting_exact() {
    // A flood of well-formed transform requests races two hostile
    // riders — an absurd length prefix and a half-written staller —
    // against a deliberately tight queue. Accounting must stay exact:
    // every well-formed request is served or explicitly shed, the
    // riders appear in neither counter, and the latency recorder holds
    // precisely the answered requests.
    let opts = ServerOptions {
        batch_window: Duration::from_millis(60),
        max_batch: 8,
        max_queue: 4,
        read_timeout: Duration::from_millis(300),
        ..Default::default()
    };
    let server = TransformServer::start("127.0.0.1:0", test_model(5), opts).unwrap();
    let addr = server.addr();

    let nreq = 16;
    let barrier = Barrier::new(nreq + 2);
    let mut served = 0u32;
    let mut shed = 0u32;
    std::thread::scope(|sc| {
        // Rider 1: oversized batch claim fired mid-storm. Must be
        // refused before allocation without disturbing the flood.
        sc.spawn(|| {
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            barrier.wait();
            s.write_all(&(1u32 << 26).to_le_bytes()).unwrap();
            let err = read_reply(&mut s).unwrap_err();
            assert!(err.contains("exceeds server limit"), "{err}");
        });
        // Rider 2: valid prefix, 3 of the 16 promised f64s, then
        // silence. The read deadline must reap it mid-storm.
        sc.spawn(|| {
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            barrier.wait();
            s.write_all(&(M as u32).to_le_bytes()).unwrap();
            s.write_all(&[0u8; 24]).unwrap();
            let mut probe = [0u8; 1];
            match s.read(&mut probe) {
                Ok(0) | Err(_) => {}
                Ok(n) => panic!("stalled rider should be dropped, read {n} bytes"),
            }
        });
        let handles: Vec<_> = (0..nreq)
            .map(|_| {
                let barrier = &barrier;
                sc.spawn(move || {
                    let mut client = TransformClient::connect(addr).unwrap();
                    barrier.wait();
                    client.transform(&vec![0.5; M]).map_err(|e| e.to_string())
                })
            })
            .collect();
        for h in handles {
            match h.join().unwrap() {
                Ok(code) => {
                    assert_eq!(code.len(), K);
                    served += 1;
                }
                Err(e) => {
                    assert!(e.contains("overloaded"), "unexpected reply: {e}");
                    shed += 1;
                }
            }
        }
    });

    assert_eq!(served + shed, nreq as u32);
    assert!(served > 0, "storm starved every request");
    assert_eq!(server.stats().0 as u32, served, "served-counter drift");
    assert_eq!(server.shed_count() as u32, shed, "shed-counter drift");
    let lat = server.latency_summary();
    assert_eq!(lat.count as u32, served, "latency recorder missed answered requests");
    assert!(lat.p50.is_finite() && lat.p50 >= 0.0, "p50 = {}", lat.p50);
    assert!(lat.p50 <= lat.p90 && lat.p90 <= lat.p99 && lat.p99 <= lat.max, "{lat:?}");

    assert_healthy(addr);
    server.shutdown();
}

#[test]
fn shutdown_drains_requests_in_flight() {
    let opts =
        ServerOptions { batch_window: Duration::from_millis(300), ..ServerOptions::default() };
    let server = TransformServer::start("127.0.0.1:0", test_model(4), opts).unwrap();
    let addr = server.addr();

    std::thread::scope(|sc| {
        let handles: Vec<_> = (0..6)
            .map(|_| {
                sc.spawn(move || {
                    let mut client = TransformClient::connect(addr).unwrap();
                    client.transform(&vec![0.5; M])
                })
            })
            .collect();
        // Requests are now queued inside the solver's batch window;
        // shutting down must answer them all before the threads join.
        std::thread::sleep(Duration::from_millis(120));
        server.shutdown();
        for h in handles {
            let code = h.join().unwrap().expect("request in flight at shutdown lost its reply");
            assert_eq!(code.len(), K);
        }
    });
}

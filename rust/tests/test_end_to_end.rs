//! End-to-end integration over the coordinator: datasets → jobs → solvers
//! → metrics, the classification pipeline, and the CLI binary itself.

use std::process::Command;

use randnmf::coordinator::config::Config;
use randnmf::coordinator::jobs::{DatasetSpec, Job};
use randnmf::data::digits;
use randnmf::eval::classification::Report;
use randnmf::eval::knn::Knn;
use randnmf::nmf::hals::Hals;
use randnmf::nmf::options::{NmfOptions, Regularization};
use randnmf::nmf::rhals::RandomizedHals;
use randnmf::nmf::solver::NmfSolver;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join("randnmf_e2e").join(name);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// The paper's core claim, end to end on the faces substitute: randomized
/// HALS matches deterministic HALS error at the same iteration budget.
#[test]
fn faces_rhals_matches_hals_error() {
    let x = DatasetSpec::Faces { scale: 0.06 }.build(42).unwrap();
    let opts = NmfOptions::new(8).with_max_iter(120).with_seed(1);
    let det = Hals::new(opts.clone()).fit(&x).unwrap();
    let rand = RandomizedHals::new(opts).fit(&x).unwrap();
    assert!(
        rand.final_rel_err < det.final_rel_err + 5e-3,
        "rhals {} vs hals {}",
        rand.final_rel_err,
        det.final_rel_err
    );
}

/// Table 4's pipeline: NMF features → kNN(3) → precision/recall/F1, with
/// randomized and deterministic features scoring comparably.
#[test]
fn digits_classification_pipeline() {
    let data = digits::generate(&digits::DigitsSpec {
        n_train: 400,
        n_test: 150,
        noise: 0.02,
        seed: 42,
    });
    let opts = NmfOptions::new(16).with_max_iter(50).with_seed(2);

    let mut f1s = Vec::new();
    let solvers: Vec<Box<dyn NmfSolver>> = vec![
        Box::new(Hals::new(opts.clone())),
        Box::new(RandomizedHals::new(opts)),
    ];
    for solver in solvers {
        let fit = solver.fit(&data.train_x).unwrap();
        let train_codes = fit.model.transform(&data.train_x, 50);
        let test_codes = fit.model.transform(&data.test_x, 50);
        let knn = Knn::fit(3, train_codes, data.train_y.clone());
        let pred = knn.predict(&test_codes);
        let report = Report::compute(&data.test_y, &pred);
        let (_, _, f1) = report.weighted_avg();
        assert!(f1 > 0.7, "{}: F1 too low: {f1}", solver.name());
        f1s.push(f1);
    }
    // Paper Table 4: both feature sets classify equally well.
    assert!((f1s[0] - f1s[1]).abs() < 0.08, "F1 gap: {f1s:?}");
}

/// ℓ1 regularization sparsifies the basis without hurting the fit much
/// (the Fig. 7c experiment, quantitatively).
#[test]
fn hyperspectral_l1_sparsifies_basis() {
    let x = DatasetSpec::Hyperspectral { scale: 0.08 }.build(42).unwrap();
    let base = RandomizedHals::new(NmfOptions::new(4).with_max_iter(150).with_seed(3))
        .fit(&x)
        .unwrap();
    let sparse = RandomizedHals::new(
        NmfOptions::new(4)
            .with_max_iter(150)
            .with_seed(3)
            .with_reg_w(Regularization::lasso(0.9)),
    )
    .fit(&x)
    .unwrap();
    assert!(
        sparse.model.w.zero_fraction() >= base.model.w.zero_fraction(),
        "{} vs {}",
        sparse.model.w.zero_fraction(),
        base.model.w.zero_fraction()
    );
    assert!(sparse.final_rel_err < base.final_rel_err + 0.1);
}

/// Config file → job → run records on disk.
#[test]
fn job_from_config_writes_records() {
    let dir = tmpdir("job");
    let cfg = Config::parse(&format!(
        r#"
[job]
dataset = "synthetic"
solvers = "hals, rhals, compressed-mu"
out_dir = "{}"

[data]
rows = 120
cols = 80
rank = 4
seed = 5

[solver]
rank = 4
max_iter = 60
trace_every = 10
"#,
        dir.display()
    ))
    .unwrap();
    let job = Job::from_config(&cfg).unwrap();
    let recs = job.run().unwrap();
    assert_eq!(recs.len(), 3);
    assert!(dir.join("runs.jsonl").exists());
    // traces written for each solver
    assert!(dir.join("synthetic-120x80-r4-hals.trace.csv").exists());
    assert!(dir.join("synthetic-120x80-r4-rhals.trace.csv").exists());
    // rHALS must not be slower than HALS even at this small scale… that is
    // not guaranteed on tiny data, so only check the error contract:
    assert!(recs.iter().all(|r| r.rel_err < 0.2), "{recs:?}");
}

/// Out-of-core path: gen-data → store → blocked factorization via the CLI
/// binary (true end-to-end, new process).
#[test]
fn cli_gen_data_and_factorize_blocked() {
    let dir = tmpdir("cli");
    let store = dir.join("demo.nmfstore");
    let bin = env!("CARGO_BIN_EXE_randnmf");

    let out = Command::new(bin)
        .args([
            "gen-data",
            "--dataset",
            "synthetic",
            "--rows",
            "300",
            "--cols",
            "200",
            "--data-rank",
            "6",
            "--block",
            "64",
            "--out",
            store.to_str().unwrap(),
        ])
        .output()
        .expect("spawn gen-data");
    assert!(out.status.success(), "gen-data failed: {}", String::from_utf8_lossy(&out.stderr));
    assert!(store.exists());

    let out = Command::new(bin)
        .args([
            "factorize",
            store.to_str().unwrap(),
            "--algo",
            "rhals",
            "--rank",
            "6",
            "--max-iter",
            "50",
            "--blocked",
        ])
        .output()
        .expect("spawn factorize");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "factorize failed: {}", String::from_utf8_lossy(&out.stderr));
    assert!(stdout.contains("relative error"), "stdout: {stdout}");
    // The data is exact rank 6 and the sketch holds it: error ≈ 0.
    let err: f64 = stdout
        .lines()
        .find(|l| l.contains("relative error"))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|t| t.trim().parse().ok())
        .expect("parse error value");
    assert!(err < 0.05, "blocked rhals error too large: {err}");
}

/// CLI rejects nonsense cleanly (no panic, helpful message).
#[test]
fn cli_error_paths() {
    let bin = env!("CARGO_BIN_EXE_randnmf");
    let out = Command::new(bin).args(["bogus-subcommand"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));

    let out = Command::new(bin).args(["run"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--config"));

    let out = Command::new(bin).args(["help"]).output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("factorize"));
}

/// Interleaved update order (Eq. 23) through the public API reaches a
/// similar fit to blocked (Eq. 24) on small data.
#[test]
fn update_order_ablation_consistency() {
    use randnmf::nmf::options::UpdateOrder;
    let x = DatasetSpec::Synthetic { m: 60, n: 50, r: 3, noise: 0.0 }.build(7).unwrap();
    let mut errs = Vec::new();
    for order in [UpdateOrder::BlockedCyclic, UpdateOrder::InterleavedCyclic, UpdateOrder::Shuffled]
    {
        let fit = Hals::new(
            NmfOptions::new(3).with_max_iter(150).with_seed(8).with_update_order(order),
        )
        .fit(&x)
        .unwrap();
        errs.push(fit.final_rel_err);
    }
    for e in &errs {
        assert!(*e < 2e-2, "errors: {errs:?}");
    }
}

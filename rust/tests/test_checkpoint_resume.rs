//! Kill-and-resume bit-identity for the `.nmfckpt` checkpoint layer.
//!
//! The contract under test: a fit that is interrupted after any completed
//! sweep and resumed from its last checkpoint finishes **bit-identical**
//! to the uninterrupted run — same factors, same iteration count, same
//! convergence flag, same projected-gradient ratio, same trace (wall-clock
//! excepted). The property sweeps all three solvers (HALS, MU, randomized
//! HALS), dense and sparse input, both sweep orders, random shapes and
//! checkpoint cadences. The CI thread matrix runs this binary under
//! `RANDNMF_THREADS=1` and `=4`, covering both thread regimes.
//!
//! Deterministic edge cases ride along: a stale `.tmp` left by a kill
//! between temp-write and rename, resuming a converged fit, mismatched
//! options/solver/data (clean errors, never silent divergence), and
//! corrupt/truncated/missing checkpoint files.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

use randnmf::data::robust;
use randnmf::linalg::sparse::{CsrMat, NmfInput};
use randnmf::nmf::checkpoint;
use randnmf::nmf::hals::Hals;
use randnmf::nmf::model::NmfFit;
use randnmf::nmf::mu::Mu;
use randnmf::nmf::options::{NmfOptions, UpdateOrder};
use randnmf::nmf::rhals::RandomizedHals;
use randnmf::nmf::solver::NmfSolver;
use randnmf::prop_assert;
use randnmf::testing::fixtures::low_rank;
use randnmf::testing::forall;

fn dir() -> PathBuf {
    let d = std::env::temp_dir().join("randnmf_ckpt_resume");
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Unique checkpoint path per case (the property runs many cases; tests in
/// this binary may run concurrently).
fn ckpt_path(tag: &str) -> PathBuf {
    static SEQ: AtomicUsize = AtomicUsize::new(0);
    let n = SEQ.fetch_add(1, Ordering::Relaxed);
    dir().join(format!("{tag}_{n}.nmfckpt"))
}

fn fit(solver_id: usize, opts: NmfOptions, x: NmfInput<'_>) -> anyhow::Result<NmfFit> {
    match solver_id {
        0 => Hals::new(opts).fit_input(x),
        1 => Mu::new(opts).fit_input(x),
        _ => RandomizedHals::new(opts).fit_input(x),
    }
}

fn solver_name(solver_id: usize) -> &'static str {
    ["hals", "mu", "rhals"][solver_id]
}

/// Compare two fits bit for bit, ignoring only wall-clock fields.
fn assert_fits_bit_identical(a: &NmfFit, b: &NmfFit, what: &str) -> Result<(), String> {
    prop_assert!(a.model.w == b.model.w, "{what}: W differs");
    prop_assert!(a.model.h == b.model.h, "{what}: H differs");
    prop_assert!(a.iters == b.iters, "{what}: iters {} vs {}", a.iters, b.iters);
    prop_assert!(a.converged == b.converged, "{what}: converged flag differs");
    prop_assert!(
        a.pg_ratio.to_bits() == b.pg_ratio.to_bits(),
        "{what}: pg_ratio {} vs {}",
        a.pg_ratio,
        b.pg_ratio
    );
    prop_assert!(
        a.final_rel_err.to_bits() == b.final_rel_err.to_bits(),
        "{what}: final_rel_err {} vs {}",
        a.final_rel_err,
        b.final_rel_err
    );
    prop_assert!(
        a.trace.len() == b.trace.len(),
        "{what}: trace length {} vs {}",
        a.trace.len(),
        b.trace.len()
    );
    for (ta, tb) in a.trace.iter().zip(&b.trace) {
        prop_assert!(
            ta.iter == tb.iter
                && ta.rel_err.to_bits() == tb.rel_err.to_bits()
                && ta.pg_norm_sq.to_bits() == tb.pg_norm_sq.to_bits(),
            "{what}: trace point at iter {} differs",
            ta.iter
        );
    }
    Ok(())
}

/// The tentpole property: interrupt at a random sweep, resume, and the fit
/// must be indistinguishable (bit for bit) from never having been killed.
#[test]
fn killed_and_resumed_fits_are_bit_identical() {
    forall("kill/resume bit identity", 14, |g| {
        let solver_id = g.usize_in(0, 2);
        let m = g.usize_in(30, 48);
        let n = g.usize_in(30, 48);
        let k = g.usize_in(2, 4);
        let sparse = g.bool();
        let total = g.usize_in(6, 14);
        let every = g.usize_in(1, 3);
        // Kill somewhere after the first checkpoint, before the end.
        let cut = g.usize_in(every, total - 1);
        let order = *g.choose(&[UpdateOrder::BlockedCyclic, UpdateOrder::Shuffled]);
        let seed = g.usize_in(0, 1 << 30) as u64;

        let mut x = g.mat(m, n);
        if sparse {
            // ~60% of entries zeroed: exercises the CSR solver paths.
            for v in x.as_mut_slice().iter_mut() {
                if *v < 0.6 {
                    *v = 0.0;
                }
            }
        }
        let csr = CsrMat::from_dense(&x);
        let input = || if sparse { NmfInput::Sparse(&csr) } else { NmfInput::Dense(&x) };

        let base = NmfOptions::new(k)
            .with_seed(seed)
            .with_tol(0.0) // never converge early: both runs sweep to max_iter
            .with_trace_every(3)
            .with_update_order(order)
            .with_oversample(8);
        let path = ckpt_path("prop");
        let what = format!(
            "{} {m}x{n} k={k} sparse={sparse} order={order:?} total={total} \
             every={every} cut={cut}",
            solver_name(solver_id)
        );

        let uninterrupted = fit(solver_id, base.clone().with_max_iter(total), input())
            .map_err(|e| format!("{what}: uninterrupted fit failed: {e}"))?;

        // "Kill": the interrupted run simply stops at `cut` sweeps, having
        // published a checkpoint at the last cadence multiple <= cut.
        let interrupted = fit(
            solver_id,
            base.clone().with_max_iter(cut).with_checkpoint(&path, every),
            input(),
        )
        .map_err(|e| format!("{what}: interrupted fit failed: {e}"))?;
        prop_assert!(interrupted.iters == cut, "{what}: interrupted ran {}", interrupted.iters);
        prop_assert!(path.exists(), "{what}: no checkpoint published");

        let resumed = fit(
            solver_id,
            base.clone().with_max_iter(total).with_resume_from(&path),
            input(),
        )
        .map_err(|e| format!("{what}: resumed fit failed: {e}"))?;
        std::fs::remove_file(&path).ok();

        prop_assert!(resumed.iters == total, "{what}: resumed ran {} iters", resumed.iters);
        assert_fits_bit_identical(&uninterrupted, &resumed, &what)
    });
}

/// A kill between temp-write and rename leaves a stale `.tmp`; the next
/// checkpointed fit must plow through it and the resume must still match.
#[test]
fn stale_temp_file_never_breaks_checkpoint_or_resume() {
    let x = low_rank(36, 28, 3, 11);
    let path = ckpt_path("staletmp");
    let base = NmfOptions::new(3).with_seed(7).with_tol(0.0).with_trace_every(2);

    let uninterrupted = Hals::new(base.clone().with_max_iter(10)).fit(&x).unwrap();

    // Garbage where the next write will stage its temp file.
    std::fs::write(checkpoint::tmp_path(&path), b"half-written garbage from a kill").unwrap();
    let interrupted =
        Hals::new(base.clone().with_max_iter(6).with_checkpoint(&path, 2)).fit(&x).unwrap();
    assert_eq!(interrupted.iters, 6);
    assert!(!checkpoint::tmp_path(&path).exists(), "publish must consume the temp file");

    let resumed =
        Hals::new(base.clone().with_max_iter(10).with_resume_from(&path)).fit(&x).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(resumed.iters, 10);
    assert_eq!(resumed.model.w, uninterrupted.model.w);
    assert_eq!(resumed.model.h, uninterrupted.model.h);
}

/// Resuming a fit that already converged re-detects convergence at the
/// restored sweep and returns the identical result — no extra updates.
#[test]
fn checkpoint_of_converged_fit_resumes_cleanly() {
    let x = low_rank(40, 30, 3, 13);
    let path = ckpt_path("converged");
    let base = NmfOptions::new(3).with_seed(5).with_tol(1e-3).with_trace_every(1);

    let first =
        Hals::new(base.clone().with_max_iter(400).with_checkpoint(&path, 1)).fit(&x).unwrap();
    assert!(first.converged, "fixture must converge (ran {} iters)", first.iters);

    let resumed =
        Hals::new(base.clone().with_max_iter(400).with_resume_from(&path)).fit(&x).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(resumed.converged);
    assert_eq!(resumed.iters, first.iters);
    assert_eq!(resumed.model.w, first.model.w);
    assert_eq!(resumed.model.h, first.model.h);
    assert_eq!(resumed.pg_ratio.to_bits(), first.pg_ratio.to_bits());
}

/// Every mismatch between a checkpoint and the fit consuming it is a
/// clean, specific error — wrong options, wrong solver, wrong data.
#[test]
fn mismatched_resume_is_a_clean_error() {
    let x = low_rank(32, 24, 3, 17);
    let path = ckpt_path("mismatch");
    let base = NmfOptions::new(3).with_seed(9).with_tol(0.0);
    Hals::new(base.clone().with_max_iter(4).with_checkpoint(&path, 2)).fit(&x).unwrap();

    // Different seed -> different options hash.
    let err = Hals::new(base.clone().with_seed(10).with_max_iter(8).with_resume_from(&path))
        .fit(&x)
        .unwrap_err();
    assert!(err.to_string().contains("hash"), "{err}");

    // Different solver.
    let err =
        Mu::new(base.clone().with_max_iter(8).with_resume_from(&path)).fit(&x).unwrap_err();
    assert!(err.to_string().contains("solver"), "{err}");

    // Different data (same shape, different ||X||^2 fingerprint).
    let y = low_rank(32, 24, 3, 18);
    let err = Hals::new(base.clone().with_max_iter(8).with_resume_from(&path))
        .fit(&y)
        .unwrap_err();
    assert!(err.to_string().contains("different matrix"), "{err}");
    std::fs::remove_file(&path).ok();
}

/// Damaged or absent checkpoint files surface typed errors, never panics
/// or silent fresh starts.
#[test]
fn corrupt_truncated_or_missing_checkpoint_is_rejected() {
    let x = low_rank(28, 22, 2, 19);
    let path = ckpt_path("corrupt");
    let base = NmfOptions::new(2).with_seed(3).with_tol(0.0);
    Hals::new(base.clone().with_max_iter(3).with_checkpoint(&path, 1)).fit(&x).unwrap();
    let good = std::fs::read(&path).unwrap();

    // Bit flip in the factor payload: CRC catches it, classified Corrupt.
    let mut bad = good.clone();
    let mid = good.len() / 2;
    bad[mid] ^= 0x01;
    std::fs::write(&path, &bad).unwrap();
    let err = Hals::new(base.clone().with_max_iter(8).with_resume_from(&path))
        .fit(&x)
        .unwrap_err();
    assert!(err.to_string().contains("CRC"), "{err}");
    assert_eq!(robust::classify(&err), robust::FaultKind::Corrupt);

    // Truncation.
    std::fs::write(&path, &good[..good.len() / 3]).unwrap();
    let err = Hals::new(base.clone().with_max_iter(8).with_resume_from(&path))
        .fit(&x)
        .unwrap_err();
    assert_eq!(robust::classify(&err), robust::FaultKind::Corrupt, "{err}");

    // Missing file.
    std::fs::remove_file(&path).ok();
    assert!(Hals::new(base.clone().with_max_iter(8).with_resume_from(&path)).fit(&x).is_err());
}

/// The interleaved ablation path refuses checkpoint/resume up front
/// instead of silently ignoring the request.
#[test]
fn interleaved_order_rejects_checkpointing_up_front() {
    let x = low_rank(20, 16, 2, 23);
    let path = ckpt_path("interleaved");
    let opts = NmfOptions::new(2)
        .with_seed(1)
        .with_max_iter(4)
        .with_update_order(UpdateOrder::InterleavedCyclic)
        .with_checkpoint(&path, 1);
    let err = Hals::new(opts).fit(&x).unwrap_err();
    assert!(err.to_string().contains("checkpoint"), "{err}");
    assert!(!path.exists());
}

//! Zero-allocation guarantee for the **multithreaded** (pool) path.
//!
//! The sibling `test_zero_alloc.rs` pins `RANDNMF_THREADS=1` and verifies
//! the single-threaded `Workspace` path. This binary pins
//! `RANDNMF_THREADS=4` *before the thread-count `OnceLock` is first
//! touched* and uses shapes large enough to trip the GEMM parallelism
//! threshold, so every `_into` kernel call below actually dispatches onto
//! the persistent worker pool — and must still allocate nothing once the
//! per-worker scratch is warm:
//!
//! * pool dispatch itself (wake + join of parked workers) is
//!   allocation-free,
//! * warm threaded `_into` kernels allocate exactly zero,
//! * full HALS / randomized-HALS fits have allocation counts independent
//!   of the iteration count,
//! * a warm `RandomizedHals::fit_with` on a reused `RhalsScratch` — the
//!   whole Algorithm 1 pipeline, compression stage included — performs
//!   exactly zero heap allocations,
//! * a warm `Transform::transform_with` stays allocation-free even when
//!   the batch is big enough that the NNLS sweep itself fans out onto
//!   the pool (the `b·k²` sweep gate and the GEMM gate both tripped).
//!
//! Caveat: the counting allocator sees every thread, so the warmup phase
//! must drive each worker's scratch (pack panels + partial buffers) to
//! its capacity fixed point before counting starts — job→worker
//! assignment and chunk boundaries are deterministic, so identical calls
//! reuse identical buffers.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

// SAFETY: a counting pass-through — every call forwards verbatim to the
// System allocator, which upholds the GlobalAlloc contract; the only
// extra work is a relaxed atomic increment with no aliasing or layout
// implications.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: forwards the caller's layout unchanged to System.alloc.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    // SAFETY: forwards ptr/layout unchanged to System.dealloc.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    // SAFETY: forwards ptr/layout/new_size unchanged to System.realloc.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    // SAFETY: forwards the caller's layout unchanged to System.alloc_zeroed.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

use randnmf::linalg::gemm;
use randnmf::linalg::mat::Mat;
use randnmf::linalg::pool;
use randnmf::linalg::rng::Pcg64;
use randnmf::linalg::sparse::{CsrMat, SparseMat};
use randnmf::linalg::workspace::Workspace;
use randnmf::nmf::hals::{Hals, HalsScratch};
use randnmf::nmf::mu::{Mu, MuScratch};
use randnmf::nmf::options::NmfOptions;
use randnmf::nmf::rhals::{RandomizedHals, RhalsScratch};
use randnmf::nmf::transform::{Transform, TransformOptions, TransformScratch};
use randnmf::nmf::twosided::{TwoSidedHals, TwoSidedScratch};
use randnmf::sketch::qb::{qb_into, QbOptions, SketchKind};
use randnmf::sketch::srht::srht_sketch_apply;
use randnmf::testing::fixtures::low_rank;

fn hals_fit_allocs(x: &Mat, iters: usize) -> u64 {
    let solver =
        Hals::new(NmfOptions::new(8).with_max_iter(iters).with_tol(0.0).with_seed(7));
    let before = allocs();
    let fit = solver.fit(x).unwrap();
    let after = allocs();
    assert_eq!(fit.iters, iters);
    after - before
}

fn rhals_fit_allocs(x: &Mat, iters: usize, batched: bool) -> u64 {
    let solver = RandomizedHals::new(
        NmfOptions::new(8)
            .with_max_iter(iters)
            .with_tol(0.0)
            .with_seed(9)
            .with_oversample(6)
            .with_batched_projection(batched),
    );
    let before = allocs();
    let fit = solver.fit(x).unwrap();
    let after = allocs();
    assert_eq!(fit.iters, iters);
    after - before
}

#[test]
fn threaded_steady_state_iterations_do_not_allocate() {
    // Must precede the first touch of the thread-count OnceLock.
    std::env::set_var("RANDNMF_THREADS", "4");
    assert_eq!(gemm::num_threads(), 4, "test requires the pinned pool size");

    // --- (a) bare pool dispatch is allocation-free once workers exist ---
    {
        let mut sess = pool::session();
        for _ in 0..3 {
            sess.run(pool::max_jobs(), &|_j, _s| {}); // warmup: spawn + park
        }
        let before = allocs();
        for _ in 0..100 {
            sess.run(pool::max_jobs(), &|_j, _s| {});
        }
        assert_eq!(allocs() - before, 0, "pool dispatch must not allocate");
    }

    // --- (b) warm threaded `_into` kernels allocate exactly zero ---
    // Shapes exceed the 2·m·n·k ≥ 2²⁰ threading gate, so each call below
    // fans out onto the pool (row split for matmul/a_bt, inner split for
    // at_b/gram/gram_t).
    let mut rng = Pcg64::seed_from_u64(1);
    let a = rng.uniform_mat(256, 64);
    let b = rng.uniform_mat(64, 128);
    let tall = rng.uniform_mat(2000, 24);
    let wide = rng.uniform_mat(24, 2000);
    let bt = rng.uniform_mat(128, 64);
    let mut ws = Workspace::new();
    let mut c = Mat::zeros(256, 128);
    let mut atb = Mat::zeros(24, 24);
    let mut abt = Mat::zeros(256, 128);
    let mut gr = Mat::zeros(24, 24);
    let mut gt = Mat::zeros(24, 24);
    for _ in 0..5 {
        // warmup: grows per-worker pack panels + partial buffers to their
        // fixed point (deterministic job→worker assignment reuses them)
        gemm::matmul_into(&a, &b, &mut c, &mut ws);
        gemm::at_b_into(&tall, &tall, &mut atb, &mut ws);
        gemm::a_bt_into(&a, &bt, &mut abt, &mut ws);
        gemm::gram_into(&tall, &mut gr, &mut ws);
        gemm::gram_t_into(&wide, &mut gt, &mut ws);
    }
    let before = allocs();
    for _ in 0..20 {
        gemm::matmul_into(&a, &b, &mut c, &mut ws);
        gemm::at_b_into(&tall, &tall, &mut atb, &mut ws);
        gemm::a_bt_into(&a, &bt, &mut abt, &mut ws);
        gemm::gram_into(&tall, &mut gr, &mut ws);
        gemm::gram_t_into(&wide, &mut gt, &mut ws);
    }
    assert_eq!(
        allocs() - before,
        0,
        "warm threaded _into kernels must not allocate at all"
    );

    // --- (c) solver fits: allocation count independent of iteration count ---
    // 500×300 at k=8 puts the big products (XHᵀ, XᵀW) on the pool path.
    let x = low_rank(500, 300, 8, 3);

    let _ = hals_fit_allocs(&x, 5); // throwaway: settles worker scratch
    let hals_short = hals_fit_allocs(&x, 20);
    let hals_long = hals_fit_allocs(&x, 70);
    assert_eq!(
        hals_long, hals_short,
        "threaded HALS allocated {} extra times over 50 extra iterations",
        hals_long.abs_diff(hals_short)
    );

    for batched in [false, true] {
        let _ = rhals_fit_allocs(&x, 5, batched); // throwaway warmup
        let short = rhals_fit_allocs(&x, 20, batched);
        let long = rhals_fit_allocs(&x, 70, batched);
        assert_eq!(
            long, short,
            "threaded rHALS (batched={batched}) allocated {} extra times \
             over 50 extra iterations",
            long.abs_diff(short)
        );
    }

    // --- (d) warm fit_with on the pool path: the whole randomized fit —
    //     compression stage included, with its big XΩ/XᵀQ/XQ products
    //     fanning out onto the parked workers — allocates exactly zero ---
    // Noise keeps the sketches full-rank so the CholeskyQR2 (Gram) QR
    // path runs too; its gram products stay on the same engine.
    let mut noisy = x.clone();
    let mut nrng = Pcg64::seed_from_u64(20);
    let noise = nrng.uniform_mat(noisy.rows(), noisy.cols());
    noisy.axpy(1e-3, &noise);
    for (data, label) in [(&x, "exact low rank"), (&noisy, "noisy low rank")] {
        let solver = RandomizedHals::new(
            NmfOptions::new(8)
                .with_max_iter(12)
                .with_tol(0.0)
                .with_seed(21)
                .with_oversample(6),
        );
        let mut scratch = RhalsScratch::new();
        for _ in 0..3 {
            // Warmup: settles both the workspace pool and each worker's
            // persistent scratch at their capacity fixed points.
            let fit = solver.fit_with(data, &mut scratch).unwrap();
            fit.recycle(&mut scratch.ws);
        }
        for round in 0..3 {
            let before = allocs();
            let fit = solver.fit_with(data, &mut scratch).unwrap();
            let n = allocs() - before;
            fit.recycle(&mut scratch.ws);
            assert_eq!(
                n, 0,
                "{label}: warm threaded fit_with round {round} performed {n} \
                 heap allocations"
            );
        }
    }

    // --- (e) sparse CSR input on the pool path: shapes big enough that
    //     2·nnz·l clears the 2²⁰ gate, so the CSR sketch (row split) and
    //     XᵀQ (inner split over pool workers) actually fan out — and a
    //     warm sparse fit_with must still allocate exactly zero ---
    let mut srng = Pcg64::seed_from_u64(30);
    let xs = randnmf::data::synthetic::sparse_low_rank(2000, 600, 8, 0.1, &mut srng);
    assert!(2 * xs.nnz() * 14 >= 1 << 20, "shape must trip the sparse threading gate");
    let solver = RandomizedHals::new(
        NmfOptions::new(8)
            .with_max_iter(10)
            .with_tol(0.0)
            .with_seed(31)
            .with_oversample(6),
    );
    let mut scratch = RhalsScratch::new();
    for _ in 0..3 {
        let fit = solver.fit_with(&xs, &mut scratch).unwrap();
        fit.recycle(&mut scratch.ws);
    }
    for round in 0..3 {
        let before = allocs();
        let fit = solver.fit_with(&xs, &mut scratch).unwrap();
        let n = allocs() - before;
        fit.recycle(&mut scratch.ws);
        assert_eq!(
            n, 0,
            "sparse input: warm threaded fit_with round {round} performed {n} \
             heap allocations"
        );
    }

    // --- (f) deterministic solvers on dual-storage sparse input, pool
    //     path: the same 2000×600 shape trips the 2·nnz·k gate for the
    //     k=8 numerators (2·nnz·8 ≥ 2²⁰ given nnz ≈ 120k), so the CSR
    //     row split (XHᵀ) and the CSC reduce-free row split (XᵀW) both
    //     fan out onto parked workers — and a warm `Hals::fit_with` /
    //     `Mu::fit_with` must still allocate exactly zero.
    let xd = SparseMat::new(xs.clone());
    assert!(2 * xd.nnz() * 8 >= 1 << 20, "shape must trip the sparse threading gate");
    {
        let solver = Hals::new(
            NmfOptions::new(8).with_max_iter(10).with_tol(0.0).with_seed(33),
        );
        let mut scratch = HalsScratch::new();
        for _ in 0..3 {
            let fit = solver.fit_with(&xd, &mut scratch).unwrap();
            fit.recycle(&mut scratch.ws);
        }
        assert!(xd.mirror_built(), "warmup must have built the CSC mirror");
        for round in 0..3 {
            let before = allocs();
            let fit = solver.fit_with(&xd, &mut scratch).unwrap();
            let n = allocs() - before;
            fit.recycle(&mut scratch.ws);
            assert_eq!(
                n, 0,
                "sparse deterministic HALS: warm threaded fit_with round {round} \
                 performed {n} heap allocations"
            );
        }
    }
    {
        let solver = Mu::new(
            NmfOptions::new(8).with_max_iter(10).with_tol(0.0).with_seed(34),
        );
        let mut scratch = MuScratch::new();
        for _ in 0..3 {
            let fit = solver.fit_with(&xd, &mut scratch).unwrap();
            fit.recycle(&mut scratch.ws);
        }
        for round in 0..3 {
            let before = allocs();
            let fit = solver.fit_with(&xd, &mut scratch).unwrap();
            let n = allocs() - before;
            fit.recycle(&mut scratch.ws);
            assert_eq!(
                n, 0,
                "sparse MU: warm threaded fit_with round {round} performed {n} \
                 heap allocations"
            );
        }
    }

    // --- (g) checkpointing armed but not firing costs exactly zero on the
    //     pool path too: the checkpoint plumbing must not disturb the
    //     allocation fixed point of a warm threaded fit_with ---
    let ckpt = std::env::temp_dir().join("randnmf_zero_alloc_pool_unfired.nmfckpt");
    std::fs::remove_file(&ckpt).ok();
    let solver = RandomizedHals::new(
        NmfOptions::new(8)
            .with_max_iter(12)
            .with_tol(0.0)
            .with_seed(21)
            .with_oversample(6)
            .with_checkpoint(&ckpt, 1000),
    );
    let mut scratch = RhalsScratch::new();
    for _ in 0..3 {
        let fit = solver.fit_with(&x, &mut scratch).unwrap();
        fit.recycle(&mut scratch.ws);
    }
    for round in 0..3 {
        let before = allocs();
        let fit = solver.fit_with(&x, &mut scratch).unwrap();
        let n = allocs() - before;
        fit.recycle(&mut scratch.ws);
        assert_eq!(
            n, 0,
            "checkpoint-armed (cadence never firing) warm threaded fit_with \
             round {round} performed {n} heap allocations"
        );
    }
    assert!(!ckpt.exists(), "an unfired cadence must write nothing");

    // --- (h) serving path on the pool: batch shapes chosen to trip BOTH
    //     threading gates — b·k² = 1024·16² = 2¹⁸ fans the HALS sweep
    //     onto `run_row_split`, and 2·m·b·k = 2·512·1024·16 = 2²⁴ puts
    //     the XᵀW numerator on the threaded GEMM path — and a warm
    //     `Transform::transform_with` must still allocate exactly zero,
    //     for dense and CSR batches alike ---
    let mut trng = Pcg64::seed_from_u64(40);
    let wt = trng.uniform_mat(512, 16).map(|v| v + 0.05);
    let xb = trng.uniform_mat(512, 1024);
    let xs_batch = CsrMat::from_dense(&xb.map(|v| if v < 0.5 { 0.0 } else { v }));
    assert!(xb.cols() * 16 * 16 >= 1 << 18, "batch must trip the sweep threading gate");
    assert!(2 * wt.rows() * xb.cols() * 16 >= 1 << 20, "batch must trip the GEMM gate");
    let t = Transform::new(wt, TransformOptions::default().with_sweeps(12)).unwrap();
    let mut scratch = TransformScratch::new();
    for _ in 0..3 {
        // Warmup: settles the scratch pool and each pool worker's
        // persistent scratch at their capacity fixed points.
        let h = t.transform_with(&xb, &mut scratch).unwrap();
        scratch.recycle(h);
        let h = t.transform_with(&xs_batch, &mut scratch).unwrap();
        scratch.recycle(h);
    }
    for round in 0..3 {
        let before = allocs();
        let h = t.transform_with(&xb, &mut scratch).unwrap();
        scratch.recycle(h);
        let h = t.transform_with(&xs_batch, &mut scratch).unwrap();
        scratch.recycle(h);
        let n = allocs() - before;
        assert_eq!(
            n, 0,
            "serving path: warm threaded transform_with round {round} performed \
             {n} heap allocations (both thread-gates tripped)"
        );
    }

    // --- (i) SRHT sketch on the pool path: 500×300 pads to n_pad = 512,
    //     so the FWHT flop estimate 2·500·512·9 ≈ 2²² clears the 2²⁰ gate
    //     and the per-row transforms fan out onto `run_row_split`, staging
    //     from each worker's persistent scratch — a warm `qb_into` with
    //     the SRHT sketch (and the bare apply) must still allocate zero ---
    {
        assert!(
            2 * x.rows() * 512 * 9 >= 1 << 20,
            "shape must trip the FWHT threading gate"
        );
        let srht_opts = QbOptions::new(8).with_oversample(6).with_sketch(SketchKind::Srht);
        let l = srht_opts.sketch_width(x.rows(), x.cols());
        let mut q = Mat::zeros(x.rows(), l);
        let mut bm = Mat::zeros(l, x.cols());
        let mut y = Mat::zeros(x.rows(), l);
        for _ in 0..3 {
            let mut rng = Pcg64::seed_from_u64(50);
            qb_into(&x, srht_opts, &mut rng, &mut q, &mut bm, &mut ws);
            srht_sketch_apply((&x).into(), l, &mut rng, &mut y, &mut ws);
        }
        for round in 0..3 {
            let before = allocs();
            let mut rng = Pcg64::seed_from_u64(50);
            qb_into(&x, srht_opts, &mut rng, &mut q, &mut bm, &mut ws);
            srht_sketch_apply((&x).into(), l, &mut rng, &mut y, &mut ws);
            let n = allocs() - before;
            assert_eq!(
                n, 0,
                "SRHT sketch: warm threaded qb_into/apply round {round} performed \
                 {n} heap allocations"
            );
        }
    }

    // --- (j) two-sided fit on the pool path: both compressions (right QB
    //     + left sketch over the 500-row range) and the iteration loop's
    //     big products (BᵀW̃, C·PᵀHᵀ, QᵀW) all fan out onto the pool, and
    //     a warm `TwoSidedHals::fit_with` must still allocate exactly
    //     zero on a reused `TwoSidedScratch` ---
    for sketch in [SketchKind::Uniform, SketchKind::Srht] {
        let solver = TwoSidedHals::new(
            NmfOptions::new(8)
                .with_max_iter(10)
                .with_tol(0.0)
                .with_seed(51)
                .with_oversample(6)
                .with_sketch(sketch),
        );
        let mut scratch = TwoSidedScratch::new();
        for _ in 0..3 {
            let fit = solver.fit_with(&x, &mut scratch).unwrap();
            fit.recycle(&mut scratch.ws);
        }
        for round in 0..3 {
            let before = allocs();
            let fit = solver.fit_with(&x, &mut scratch).unwrap();
            let n = allocs() - before;
            fit.recycle(&mut scratch.ws);
            assert_eq!(
                n, 0,
                "{sketch:?}: warm threaded two-sided fit_with round {round} \
                 performed {n} heap allocations"
            );
        }
    }
}

//! Cross-engine integration: the AOT-compiled XLA path must agree with the
//! pure-Rust CPU path.
//!
//! These tests need `artifacts/` (run `make artifacts`); they are skipped
//! with a notice when the manifest is absent so `cargo test` works on a
//! fresh checkout.

use randnmf::linalg::gemm;
use randnmf::linalg::mat::Mat;
use randnmf::linalg::rng::Pcg64;
use randnmf::nmf::options::NmfOptions;
use randnmf::runtime::engine::{rhals_fit_with_engine, CpuEngine, NmfEngine, XlaEngine};
use randnmf::runtime::registry::ArtifactRegistry;

fn registry() -> Option<ArtifactRegistry> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match ArtifactRegistry::load(&dir) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("SKIP (no artifacts: {e}); run `make artifacts`");
            None
        }
    }
}

/// The quickstart artifact shape: m=500, n=400, k=8, l=28.
fn quickstart_data(seed: u64) -> Mat {
    let mut rng = Pcg64::seed_from_u64(seed);
    let u = rng.uniform_mat(500, 8);
    let v = rng.uniform_mat(8, 400);
    let mut x = gemm::matmul(&u, &v);
    let noise = rng.uniform_mat(500, 400);
    x.axpy(1e-3, &noise);
    x
}

#[test]
fn xla_rhals_iteration_matches_cpu() {
    let Some(reg) = registry() else { return };
    let engine = XlaEngine::new(reg);
    let x = quickstart_data(1);
    let mut rng = Pcg64::seed_from_u64(2);
    let omega = rng.uniform_mat(400, 28);

    let factors = CpuEngine.qb_sketch(&x, &omega, 2).unwrap();
    let opts = NmfOptions::new(8);
    let (w0, ht0) = randnmf::nmf::init::initialize_from_qb(
        &factors.q,
        &factors.b,
        x.sum() / x.len() as f64,
        &opts,
        &mut rng,
    );
    let wt0 = gemm::at_b(&factors.q, &w0);

    // One iteration on each engine from identical state.
    let (mut wc, mut wtc, mut htc) = (w0.clone(), wt0.clone(), ht0.clone());
    CpuEngine.rhals_iteration(&factors.b, &factors.q, &mut wc, &mut wtc, &mut htc).unwrap();
    let (mut wx, mut wtx, mut htx) = (w0, wt0, ht0);
    engine.rhals_iteration(&factors.b, &factors.q, &mut wx, &mut wtx, &mut htx).unwrap();

    // f32 vs f64: agree to ~1e-3 relative on the factor scale.
    let scale = wc.max().max(1e-9);
    assert!(wx.max_abs_diff(&wc) / scale < 5e-3, "W diff {}", wx.max_abs_diff(&wc) / scale);
    let hscale = htc.max().max(1e-9);
    assert!(htx.max_abs_diff(&htc) / hscale < 5e-3, "H diff {}", htx.max_abs_diff(&htc) / hscale);
    assert!(wx.is_nonneg() && htx.is_nonneg());
}

#[test]
fn xla_qb_sketch_is_valid_decomposition() {
    let Some(reg) = registry() else { return };
    let engine = XlaEngine::new(reg);
    let x = quickstart_data(3);
    let mut rng = Pcg64::seed_from_u64(4);
    let omega = rng.uniform_mat(400, 28);
    let f = engine.qb_sketch(&x, &omega, 2).unwrap();
    assert_eq!(f.q.shape(), (500, 28));
    assert_eq!(f.b.shape(), (28, 400));
    // The f32 CholeskyQR path zeroes basis directions below its numerical
    // floor (rank-revealing); live columns must be orthonormal and the
    // reconstruction near-exact regardless.
    let qtq = gemm::gram(&f.q);
    let mut live = 0;
    for i in 0..28 {
        if qtq.get(i, i) > 0.5 {
            live += 1;
            for j in 0..28 {
                if qtq.get(j, j) > 0.5 {
                    let expect = if i == j { 1.0 } else { 0.0 };
                    assert!(
                        (qtq.get(i, j) - expect).abs() < 1e-3,
                        "live block not orthonormal at ({i},{j}): {}",
                        qtq.get(i, j)
                    );
                }
            }
        }
    }
    assert!(live >= 8, "at least the true rank must survive: {live}");
    assert!(f.relative_error(&x) < 1e-2, "err={}", f.relative_error(&x));
}

#[test]
fn xla_full_fit_matches_cpu_quality() {
    let Some(reg) = registry() else { return };
    let x = quickstart_data(5);
    let opts = NmfOptions::new(8).with_max_iter(100).with_seed(6);

    let cpu_fit = rhals_fit_with_engine(&CpuEngine, &x, &opts).unwrap();
    let engine = XlaEngine::new(reg);
    let xla_fit = rhals_fit_with_engine(&engine, &x, &opts).unwrap();

    assert!(xla_fit.model.w.is_nonneg() && xla_fit.model.h.is_nonneg());
    assert!(
        (xla_fit.final_rel_err - cpu_fit.final_rel_err).abs() < 5e-3,
        "xla={} cpu={}",
        xla_fit.final_rel_err,
        cpu_fit.final_rel_err
    );
    assert!(xla_fit.final_rel_err < 5e-2);
}

#[test]
fn xla_hals_iteration_descends() {
    let Some(reg) = registry() else { return };
    let engine = XlaEngine::new(reg);
    let x = quickstart_data(7);
    let mut rng = Pcg64::seed_from_u64(8);
    let opts = NmfOptions::new(8);
    let (mut w, mut ht) = randnmf::nmf::init::initialize(&x, &opts, &mut rng);
    let e0 = randnmf::linalg::norms::relative_error(&x, &w, &ht.transpose());
    for _ in 0..60 {
        engine.hals_iteration(&x, &mut w, &mut ht).unwrap();
    }
    let e1 = randnmf::linalg::norms::relative_error(&x, &w, &ht.transpose());
    assert!(e1 < e0, "{e0} -> {e1}");
    assert!(e1 < 0.1, "e1={e1}");
}

#[test]
fn missing_shape_errors_cleanly() {
    let Some(reg) = registry() else { return };
    let engine = XlaEngine::new(reg);
    let x = Mat::zeros(33, 17);
    let mut w = Mat::zeros(33, 4);
    let mut ht = Mat::zeros(17, 4);
    let err = engine.hals_iteration(&x, &mut w, &mut ht);
    assert!(err.is_err(), "unknown shape must not silently fall back");
}

//! Zero-allocation guarantee for steady-state solver iterations — and,
//! since the sketch layer became a workspace-drawn engine, for the whole
//! Algorithm 1 pipeline.
//!
//! A counting global allocator wraps `System`; the test then asserts that
//! (a) the `_into` GEMM kernels allocate nothing once their `Workspace`
//! is warm, (b) a HALS / randomized-HALS fit's total allocation count
//! is *independent of the iteration count*, (c) a randomized fit's
//! allocation count is *independent of the power-iteration count* — i.e.
//! each compression pass (QR included) is allocation-free once warm —
//! and (d) the strongest form: a **warm `RandomizedHals::fit_with` on a
//! reused `RhalsScratch` performs exactly zero heap allocations for the
//! entire fit, compression stage included** (factors recycled between
//! fits; random init, tracing off). The serving hot path gets the same
//! treatment: a warm `Transform::transform_with` on a reused
//! `TransformScratch` allocates exactly zero for dense and CSR batches.
//!
//! Everything runs in a single `#[test]` so `RANDNMF_THREADS=1` is set
//! before the thread-count `OnceLock` is first touched. This binary
//! covers the single-threaded `Workspace` path; the multithreaded path —
//! persistent pool workers with their own scratch — is covered by the
//! sibling `test_zero_alloc_pool.rs` under `RANDNMF_THREADS=4`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

// SAFETY: a counting pass-through — every call forwards verbatim to the
// System allocator, which upholds the GlobalAlloc contract; the only
// extra work is a relaxed atomic increment with no aliasing or layout
// implications.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: forwards the caller's layout unchanged to System.alloc.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    // SAFETY: forwards ptr/layout unchanged to System.dealloc.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    // SAFETY: forwards ptr/layout/new_size unchanged to System.realloc.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    // SAFETY: forwards the caller's layout unchanged to System.alloc_zeroed.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

use randnmf::linalg::gemm;
use randnmf::linalg::mat::Mat;
use randnmf::linalg::rng::Pcg64;
use randnmf::linalg::sparse::{CsrMat, SparseMat};
use randnmf::linalg::workspace::Workspace;
use randnmf::nmf::hals::{Hals, HalsScratch};
use randnmf::nmf::mu::{Mu, MuScratch};
use randnmf::nmf::options::{NmfOptions, UpdateOrder};
use randnmf::nmf::rhals::{RandomizedHals, RhalsScratch};
use randnmf::nmf::transform::{Transform, TransformOptions, TransformScratch};
use randnmf::nmf::twosided::{TwoSidedHals, TwoSidedScratch};
use randnmf::sketch::qb::{qb_into, QbOptions, SketchKind};
use randnmf::sketch::srht::srht_sketch_apply;
use randnmf::testing::fixtures::low_rank;

/// Allocation count of one `fit_with` on an already-warm scratch (the
/// factors are recycled back into the pool afterwards, so consecutive
/// calls see an identical pool state).
fn warm_fit_with_allocs(solver: &RandomizedHals, x: &Mat, scratch: &mut RhalsScratch) -> u64 {
    let before = allocs();
    let fit = solver.fit_with(x, scratch).unwrap();
    let after = allocs();
    fit.recycle(&mut scratch.ws);
    after - before
}

/// Assert a warm `fit_with` performs exactly zero heap allocations on
/// `x`, end to end (compression stage included).
fn assert_warm_fit_allocation_free(x: &Mat, label: &str) {
    let solver = RandomizedHals::new(
        NmfOptions::new(4)
            .with_max_iter(15)
            .with_tol(0.0)
            .with_seed(21)
            .with_oversample(6),
    );
    let mut scratch = RhalsScratch::new();
    for _ in 0..3 {
        // Warmup: drives the workspace pool to its capacity fixed point.
        let fit = solver.fit_with(x, &mut scratch).unwrap();
        fit.recycle(&mut scratch.ws);
    }
    for round in 0..3 {
        let n = warm_fit_with_allocs(&solver, x, &mut scratch);
        assert_eq!(
            n, 0,
            "{label}: warm fit_with round {round} performed {n} heap allocations \
             (the whole randomized fit, compression included, must be allocation-free)"
        );
    }
}

/// Allocation count of a full deterministic-HALS fit of `iters` iterations
/// (tol = 0 and no tracing, so the loop body is the pure update path).
fn hals_fit_allocs(x: &Mat, iters: usize) -> u64 {
    let solver = Hals::new(
        NmfOptions::new(4).with_max_iter(iters).with_tol(0.0).with_seed(7),
    );
    let before = allocs();
    let fit = solver.fit(x).unwrap();
    let after = allocs();
    assert_eq!(fit.iters, iters);
    after - before
}

fn rhals_fit_allocs(x: &Mat, iters: usize, batched: bool) -> u64 {
    let solver = RandomizedHals::new(
        NmfOptions::new(4)
            .with_max_iter(iters)
            .with_tol(0.0)
            .with_seed(9)
            .with_oversample(6)
            .with_batched_projection(batched),
    );
    let before = allocs();
    let fit = solver.fit(x).unwrap();
    let after = allocs();
    assert_eq!(fit.iters, iters);
    after - before
}

#[test]
fn steady_state_iterations_do_not_allocate() {
    // Must precede the first touch of the thread-count OnceLock.
    std::env::set_var("RANDNMF_THREADS", "1");

    // --- (a) warm `_into` kernels allocate exactly zero ---
    let mut rng = Pcg64::seed_from_u64(1);
    let a = rng.uniform_mat(150, 24);
    let b = rng.uniform_mat(24, 90);
    let wide = rng.uniform_mat(12, 300);
    let mut ws = Workspace::new();
    let mut c = Mat::zeros(150, 90);
    let mut atb = Mat::zeros(24, 24);
    let mut abt = Mat::zeros(150, 150);
    let mut gr = Mat::zeros(24, 24);
    let mut gt = Mat::zeros(12, 12);
    for _ in 0..5 {
        // warmup: grows the workspace pool to its fixed point
        gemm::matmul_into(&a, &b, &mut c, &mut ws);
        gemm::at_b_into(&a, &a, &mut atb, &mut ws);
        gemm::a_bt_into(&a, &a, &mut abt, &mut ws);
        gemm::gram_into(&a, &mut gr, &mut ws);
        gemm::gram_t_into(&wide, &mut gt, &mut ws);
    }
    let before = allocs();
    for _ in 0..20 {
        gemm::matmul_into(&a, &b, &mut c, &mut ws);
        gemm::at_b_into(&a, &a, &mut atb, &mut ws);
        gemm::a_bt_into(&a, &a, &mut abt, &mut ws);
        gemm::gram_into(&a, &mut gr, &mut ws);
        gemm::gram_t_into(&wide, &mut gt, &mut ws);
    }
    assert_eq!(
        allocs() - before,
        0,
        "warm _into kernels must not allocate at all"
    );

    // --- (b) solver fits: allocation count independent of iteration count ---
    let x = low_rank(120, 80, 4, 3);

    let hals_short = hals_fit_allocs(&x, 20);
    let hals_long = hals_fit_allocs(&x, 70);
    assert_eq!(
        hals_long, hals_short,
        "HALS allocated {} extra times over 50 extra iterations",
        hals_long.saturating_sub(hals_short)
    );

    for batched in [false, true] {
        let short = rhals_fit_allocs(&x, 20, batched);
        let long = rhals_fit_allocs(&x, 70, batched);
        assert_eq!(
            long, short,
            "rHALS (batched={batched}) allocated {} extra times over 50 extra iterations",
            long.saturating_sub(short)
        );
    }

    // --- (c) compression stage: allocation count independent of the
    //     power-iteration count (each extra pass reuses the workspace) ---
    let rhals_q = |q: usize| {
        let solver = RandomizedHals::new(
            NmfOptions::new(4)
                .with_max_iter(10)
                .with_tol(0.0)
                .with_seed(9)
                .with_oversample(6)
                .with_power_iters(q),
        );
        let before = allocs();
        let fit = solver.fit(&x).unwrap();
        let after = allocs();
        assert_eq!(fit.iters, 10);
        after - before
    };
    let q2 = rhals_q(2);
    let q4 = rhals_q(4);
    assert_eq!(
        q4, q2,
        "compression passes allocated {} extra times over 2 extra power iterations",
        q4.saturating_sub(q2)
    );

    // --- (d) warm fit_with: the whole fit allocates exactly zero ---
    // Exact low-rank data drives the Householder-fallback QR path;
    // noisy data drives the CholeskyQR2 path. Both must be clean.
    assert_warm_fit_allocation_free(&x, "exact low rank (Householder fallback)");
    let mut noisy = x.clone();
    let mut nrng = Pcg64::seed_from_u64(20);
    let noise = nrng.uniform_mat(noisy.rows(), noisy.cols());
    noisy.axpy(1e-3, &noise);
    assert_warm_fit_allocation_free(&noisy, "noisy low rank (CholeskyQR2)");

    // --- (e) sparse CSR input: a warm sparse fit_with — CSR sketch,
    //     power iterations, and the O(nnz·k) exact-error epilogue — also
    //     performs exactly zero heap allocations ---
    let mut srng = Pcg64::seed_from_u64(30);
    let xs = randnmf::data::synthetic::sparse_low_rank(150, 90, 4, 0.05, &mut srng);
    let solver = RandomizedHals::new(
        NmfOptions::new(4)
            .with_max_iter(12)
            .with_tol(0.0)
            .with_seed(31)
            .with_oversample(6),
    );
    let mut scratch = RhalsScratch::new();
    for _ in 0..3 {
        let fit = solver.fit_with(&xs, &mut scratch).unwrap();
        fit.recycle(&mut scratch.ws);
    }
    for round in 0..3 {
        let before = allocs();
        let fit = solver.fit_with(&xs, &mut scratch).unwrap();
        let count = allocs() - before;
        fit.recycle(&mut scratch.ws);
        assert_eq!(
            count, 0,
            "sparse input: warm fit_with round {round} performed {count} heap \
             allocations (the CSR pipeline must be allocation-free end to end)"
        );
    }

    // --- (f) deterministic solvers on dual-storage sparse input: a warm
    //     `Hals::fit_with` / `Mu::fit_with` — sparse XHᵀ/XᵀW numerators
    //     (CSR row split + CSC reduce-free row split) and the O(nnz·k)
    //     exact-error epilogue — also performs exactly zero allocations.
    //     The CSC mirror is built during the warmup fits; warm fits only
    //     read the cached reference.
    let xd = SparseMat::new(xs.clone());
    {
        let solver = Hals::new(
            NmfOptions::new(4).with_max_iter(12).with_tol(0.0).with_seed(33),
        );
        let mut scratch = HalsScratch::new();
        for _ in 0..3 {
            let fit = solver.fit_with(&xd, &mut scratch).unwrap();
            fit.recycle(&mut scratch.ws);
        }
        assert!(xd.mirror_built(), "warmup must have built the CSC mirror");
        for round in 0..3 {
            let before = allocs();
            let fit = solver.fit_with(&xd, &mut scratch).unwrap();
            let count = allocs() - before;
            fit.recycle(&mut scratch.ws);
            assert_eq!(
                count, 0,
                "sparse deterministic HALS: warm fit_with round {round} performed \
                 {count} heap allocations"
            );
        }
    }
    {
        let solver = Mu::new(
            NmfOptions::new(4).with_max_iter(12).with_tol(0.0).with_seed(34),
        );
        let mut scratch = MuScratch::new();
        for _ in 0..3 {
            let fit = solver.fit_with(&xd, &mut scratch).unwrap();
            fit.recycle(&mut scratch.ws);
        }
        for round in 0..3 {
            let before = allocs();
            let fit = solver.fit_with(&xd, &mut scratch).unwrap();
            let count = allocs() - before;
            fit.recycle(&mut scratch.ws);
            assert_eq!(
                count, 0,
                "sparse MU: warm fit_with round {round} performed {count} heap \
                 allocations"
            );
        }
    }

    // --- (g) checkpointing armed but not firing costs exactly zero: with
    //     a cadence the fit never reaches, the checkpoint plumbing must
    //     not disturb the allocation fixed point of a warm fit_with ---
    let ckpt = std::env::temp_dir().join("randnmf_zero_alloc_unfired.nmfckpt");
    std::fs::remove_file(&ckpt).ok();
    let solver = RandomizedHals::new(
        NmfOptions::new(4)
            .with_max_iter(15)
            .with_tol(0.0)
            .with_seed(21)
            .with_oversample(6)
            .with_checkpoint(&ckpt, 1000),
    );
    let mut scratch = RhalsScratch::new();
    for _ in 0..3 {
        let fit = solver.fit_with(&x, &mut scratch).unwrap();
        fit.recycle(&mut scratch.ws);
    }
    for round in 0..3 {
        let n = warm_fit_with_allocs(&solver, &x, &mut scratch);
        assert_eq!(
            n, 0,
            "checkpoint-armed (cadence never firing) warm fit_with round {round} \
             performed {n} heap allocations"
        );
    }
    assert!(!ckpt.exists(), "an unfired cadence must write nothing");

    // --- (h) serving path: a warm `Transform::transform_with` — dense
    //     and CSR batches, fixed and Gillis-accelerated sweeps, cyclic
    //     and shuffled orders — performs exactly zero heap allocations
    //     once its `TransformScratch` is warm ---
    let mut trng = Pcg64::seed_from_u64(40);
    let w = trng.uniform_mat(120, 6).map(|v| v + 0.05);
    let xb = trng.uniform_mat(120, 40);
    let xs_batch = CsrMat::from_dense(&xb.map(|v| if v < 0.5 { 0.0 } else { v }));
    let accel = TransformOptions::default().with_sweeps(25).with_inner_tol(1e-10);
    let shuffled = TransformOptions::default()
        .with_sweeps(25)
        .with_order(UpdateOrder::Shuffled);
    let variants = [
        ("cyclic", TransformOptions::default().with_sweeps(25)),
        ("accelerated", accel),
        ("shuffled", shuffled),
    ];
    for (label, topts) in variants {
        let t = Transform::new(w.clone(), topts).unwrap();
        let mut scratch = TransformScratch::new();
        for _ in 0..3 {
            // Warmup: drives the scratch pool to its capacity fixed point
            // for both the dense and the CSR numerator path.
            let h = t.transform_with(&xb, &mut scratch).unwrap();
            scratch.recycle(h);
            let h = t.transform_with(&xs_batch, &mut scratch).unwrap();
            scratch.recycle(h);
        }
        for round in 0..3 {
            let before = allocs();
            let h = t.transform_with(&xb, &mut scratch).unwrap();
            scratch.recycle(h);
            let h = t.transform_with(&xs_batch, &mut scratch).unwrap();
            scratch.recycle(h);
            let n = allocs() - before;
            assert_eq!(
                n, 0,
                "{label}: warm transform_with round {round} performed {n} heap \
                 allocations (the serving hot path must be allocation-free)"
            );
        }
    }

    // --- (i) SRHT sketch: a warm `qb_into` with the fast-Hadamard sketch
    //     — sign/sample tables, padded staging row, FWHT, QR — draws
    //     everything from the caller workspace and allocates exactly zero
    //     once warm, and so does the bare `srht_sketch_apply` kernel ---
    {
        let srht_opts = QbOptions::new(4).with_oversample(6).with_sketch(SketchKind::Srht);
        let l = srht_opts.sketch_width(x.rows(), x.cols());
        let mut ws = Workspace::new();
        let mut q = Mat::zeros(x.rows(), l);
        let mut bm = Mat::zeros(l, x.cols());
        let mut y = Mat::zeros(x.rows(), l);
        for _ in 0..3 {
            let mut rng = Pcg64::seed_from_u64(50);
            qb_into(&x, srht_opts, &mut rng, &mut q, &mut bm, &mut ws);
            srht_sketch_apply((&x).into(), l, &mut rng, &mut y, &mut ws);
        }
        for round in 0..3 {
            let before = allocs();
            let mut rng = Pcg64::seed_from_u64(50);
            qb_into(&x, srht_opts, &mut rng, &mut q, &mut bm, &mut ws);
            srht_sketch_apply((&x).into(), l, &mut rng, &mut y, &mut ws);
            let n = allocs() - before;
            assert_eq!(
                n, 0,
                "SRHT sketch: warm qb_into/apply round {round} performed {n} heap \
                 allocations (the fast-Hadamard path must be allocation-free)"
            );
        }
    }

    // --- (j) two-sided fit: a warm `TwoSidedHals::fit_with` — both
    //     compressions (right QB + left sketch, power iterations on each
    //     side) and the full iteration loop — performs exactly zero heap
    //     allocations on a reused `TwoSidedScratch` ---
    for sketch in [SketchKind::Uniform, SketchKind::Srht] {
        let solver = TwoSidedHals::new(
            NmfOptions::new(4)
                .with_max_iter(15)
                .with_tol(0.0)
                .with_seed(51)
                .with_oversample(6)
                .with_sketch(sketch),
        );
        let mut scratch = TwoSidedScratch::new();
        for _ in 0..3 {
            let fit = solver.fit_with(&x, &mut scratch).unwrap();
            fit.recycle(&mut scratch.ws);
        }
        for round in 0..3 {
            let before = allocs();
            let fit = solver.fit_with(&x, &mut scratch).unwrap();
            let n = allocs() - before;
            fit.recycle(&mut scratch.ws);
            assert_eq!(
                n, 0,
                "{sketch:?}: warm two-sided fit_with round {round} performed {n} heap \
                 allocations (both compressions and the loop must be allocation-free)"
            );
        }
    }
}

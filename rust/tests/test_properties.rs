//! Cross-module property tests (the mini-proptest framework exercising the
//! invariants DESIGN.md §9 lists).

use randnmf::linalg::rng::Pcg64;
use randnmf::linalg::sparse::{
    csc_at_b_into, csr_at_b_into, csr_matmul_into, input_at_b_into, CscMat, CsrMat, NmfInput,
};
use randnmf::linalg::workspace::Workspace;
use randnmf::linalg::{gemm, mat::Mat, norms, qr, svd};
use randnmf::nmf::hals::{sweep_factor, Hals};
use randnmf::nmf::mu::Mu;
use randnmf::nmf::options::{NmfOptions, Regularization, UpdateOrder};
use randnmf::nmf::rhals::{RandomizedHals, RhalsScratch};
use randnmf::nmf::transform::{Transform, TransformOptions, TransformScratch};
use randnmf::nmf::twosided::TwoSidedHals;
use randnmf::nmf::update_order::OrderState;
use randnmf::prop_assert;
use randnmf::sketch::blocked::{qb_blocked, qb_blocked_sparse, CscSource, MatSource};
use randnmf::sketch::qb::{qb, QbOptions, SketchKind};
use randnmf::sketch::srht;
use randnmf::sketch::streaming::OnlineNmf;
use randnmf::testing::forall;

#[test]
fn prop_gemm_matches_naive() {
    forall("gemm == naive", 30, |g| {
        let m = g.usize_in(1, 40);
        let k = g.usize_in(1, 30);
        let n = g.usize_in(1, 40);
        let a = g.mat_gaussian(m, k);
        let b = g.mat_gaussian(k, n);
        let fast = gemm::matmul(&a, &b);
        let slow = gemm::matmul_naive(&a, &b);
        prop_assert!(fast.max_abs_diff(&slow) < 1e-10, "diff {}", fast.max_abs_diff(&slow));
        Ok(())
    });
}

#[test]
fn prop_transpose_products_consistent() {
    forall("at_b / a_bt / gram consistent", 25, |g| {
        let m = g.usize_in(1, 40);
        let k = g.usize_in(1, 12);
        let n = g.usize_in(1, 25);
        let a = g.mat_gaussian(m, k);
        let b = g.mat_gaussian(m, n);
        let c = g.mat_gaussian(n, k);
        prop_assert!(
            gemm::at_b(&a, &b).max_abs_diff(&gemm::matmul(&a.transpose(), &b)) < 1e-10,
            "at_b mismatch"
        );
        prop_assert!(
            gemm::a_bt(&a, &c).max_abs_diff(&gemm::matmul(&a, &c.transpose())) < 1e-10,
            "a_bt mismatch"
        );
        prop_assert!(
            gemm::gram(&a).max_abs_diff(&gemm::matmul(&a.transpose(), &a)) < 1e-10,
            "gram mismatch"
        );
        Ok(())
    });
}

#[test]
fn prop_into_kernels_match_naive_and_alloc_path() {
    // Every `_into` kernel against the triple-loop oracle, across shapes
    // that include 0-row/0-col/1×1 and non-multiple-of-block sizes, with a
    // reused Workspace; reuse must be bit-identical to the first pass and
    // to the allocating wrapper.
    forall("into kernels == naive, reuse bit-identical", 40, |g| {
        let m = g.usize_in(0, 70);
        let k = g.usize_in(0, 40);
        let n = g.usize_in(0, 70);
        let a = g.mat_gaussian(m, k);
        let b = g.mat_gaussian(k, n);
        let mut ws = Workspace::new();

        let naive = gemm::matmul_naive(&a, &b);
        let mut c = Mat::zeros(m, n);
        gemm::matmul_into(&a, &b, &mut c, &mut ws);
        prop_assert!(c.max_abs_diff(&naive) < 1e-9, "matmul_into vs naive");
        let first = c.clone();
        gemm::matmul_into(&a, &b, &mut c, &mut ws);
        prop_assert!(c == first, "workspace reuse not bit-identical (matmul)");
        prop_assert!(c == gemm::matmul(&a, &b), "allocating wrapper differs (matmul)");
        Ok(())
    });
}

#[test]
fn prop_transpose_into_kernels_match_naive() {
    forall("transpose into kernels == naive", 30, |g| {
        let m = g.usize_in(0, 60);
        let k = g.usize_in(0, 20);
        let n = g.usize_in(0, 40);
        let a = g.mat_gaussian(m, k);
        let b = g.mat_gaussian(m, n);
        let c_nk = g.mat_gaussian(n, k);
        let mut ws = Workspace::new();

        let mut atb = Mat::zeros(k, n);
        gemm::at_b_into(&a, &b, &mut atb, &mut ws);
        let atb_naive = gemm::matmul_naive(&a.transpose(), &b);
        prop_assert!(atb.max_abs_diff(&atb_naive) < 1e-9, "at_b_into vs naive");
        prop_assert!(atb == gemm::at_b(&a, &b), "allocating wrapper differs (at_b)");

        let mut abt = Mat::zeros(m, n);
        gemm::a_bt_into(&a, &c_nk, &mut abt, &mut ws);
        let abt_naive = gemm::matmul_naive(&a, &c_nk.transpose());
        prop_assert!(abt.max_abs_diff(&abt_naive) < 1e-9, "a_bt_into vs naive");

        let mut gr = Mat::zeros(k, k);
        gemm::gram_into(&a, &mut gr, &mut ws);
        let gr_naive = gemm::matmul_naive(&a.transpose(), &a);
        prop_assert!(gr.max_abs_diff(&gr_naive) < 1e-9, "gram_into vs naive");
        prop_assert!(gr == gr.transpose(), "gram_into not exactly symmetric");

        let mut gt = Mat::zeros(m, m);
        gemm::gram_t_into(&a, &mut gt, &mut ws);
        let gt_naive = gemm::matmul_naive(&a, &a.transpose());
        prop_assert!(gt.max_abs_diff(&gt_naive) < 1e-9, "gram_t_into vs naive");
        prop_assert!(gt == gt.transpose(), "gram_t_into not exactly symmetric");
        Ok(())
    });
}

#[test]
fn prop_triangle_gram_matches_naive_oracle() {
    // The triangle-aware Gram sweep (upper-triangle tiles only + masked
    // diagonal write-out + mirror) against the triple-loop oracle, on
    // random off-block shapes…
    forall("triangle gram == naive", 40, |g| {
        let m = g.usize_in(1, 80);
        let k = g.usize_in(1, 40);
        let a = g.mat_gaussian(m, k);
        let mut ws = Workspace::new();
        let mut gr = Mat::zeros(k, k);
        gemm::gram_into(&a, &mut gr, &mut ws);
        let oracle = gemm::matmul_naive(&a.transpose(), &a);
        prop_assert!(gr.max_abs_diff(&oracle) < 1e-9, "gram_into vs naive");
        prop_assert!(gr == gr.transpose(), "gram_into not exactly symmetric");
        let mut gt = Mat::zeros(m, m);
        gemm::gram_t_into(&a, &mut gt, &mut ws);
        let oracle_t = gemm::matmul_naive(&a, &a.transpose());
        prop_assert!(gt.max_abs_diff(&oracle_t) < 1e-9, "gram_t_into vs naive");
        prop_assert!(gt == gt.transpose(), "gram_t_into not exactly symmetric");
        Ok(())
    });
    // …and on deterministic block-edge shapes: 1×1 and every straddle of
    // the 4×8 micro-tile grid (diagonal tiles are the masked ones).
    let mut ws = Workspace::new();
    for (m, k) in [
        (1usize, 1usize),
        (3, 1),
        (10, 3),
        (10, 4),
        (10, 5),
        (20, 7),
        (20, 8),
        (20, 9),
        (33, 12),
        (33, 13),
        (50, 16),
        (50, 17),
        (64, 31),
        (64, 33),
    ] {
        let mut rng = randnmf::linalg::rng::Pcg64::seed_from_u64((m * 100 + k) as u64);
        let a = rng.gaussian_mat(m, k);
        let mut gr = Mat::zeros(k, k);
        gemm::gram_into(&a, &mut gr, &mut ws);
        let oracle = gemm::matmul_naive(&a.transpose(), &a);
        assert!(
            gr.max_abs_diff(&oracle) < 1e-10,
            "gram_into {m}x{k} off-block shape"
        );
        assert!(gr == gr.transpose(), "gram_into {m}x{k} asymmetric");
        assert!(gr == gemm::gram(&a), "allocating wrapper differs {m}x{k}");
    }
}

#[test]
fn prop_qr_reconstruction_and_orthonormality() {
    forall("QR: A = QR, QᵀQ = I", 25, |g| {
        let n = g.usize_in(1, 15);
        let m = g.usize_in(n, 60);
        let a = g.mat_gaussian(m, n);
        let f = qr::qr(&a);
        prop_assert!(
            gemm::matmul(&f.q, &f.r).max_abs_diff(&a) < 1e-9,
            "QR != A"
        );
        prop_assert!(
            gemm::gram(&f.q).max_abs_diff(&Mat::eye(n)) < 1e-9,
            "Q not orthonormal"
        );
        Ok(())
    });
}

#[test]
fn prop_svd_eckart_young() {
    forall("SVD reconstructs and orders", 15, |g| {
        let m = g.usize_in(2, 25);
        let n = g.usize_in(2, 25);
        let a = g.mat_gaussian(m, n);
        let s = svd::jacobi_svd(&a);
        let rec = s.reconstruct();
        prop_assert!(
            norms::fro_norm(&rec.sub(&a)) / norms::fro_norm(&a).max(1e-12) < 1e-8,
            "bad reconstruction"
        );
        for i in 1..s.s.len() {
            prop_assert!(s.s[i - 1] >= s.s[i] - 1e-10, "singular values unordered");
        }
        Ok(())
    });
}

#[test]
fn prop_qb_exact_on_low_rank() {
    forall("QB exact for rank <= k", 20, |g| {
        let m = g.usize_in(10, 60);
        let n = g.usize_in(10, 50);
        let r = g.usize_in(1, 5.min(m.min(n)));
        let x = g.mat_low_rank(m, n, r);
        let p = g.usize_in(2, 10);
        let q_iters = g.usize_in(0, 2);
        let mut rng = g.rng();
        let f = qb(&x, QbOptions::new(r).with_oversample(p).with_power_iters(q_iters), &mut rng);
        prop_assert!(f.relative_error(&x) < 1e-6, "err {}", f.relative_error(&x));
        Ok(())
    });
}

#[test]
fn prop_blocked_qb_bit_deterministic_across_block_sizes() {
    // The blocked engine computes over a fixed absolute chunk grid, so a
    // fixed seed must give bit-identical factors for *any* I/O block size
    // and any sketch kind.
    forall("blocked QB bitwise == any block size", 15, |g| {
        let m = g.usize_in(8, 40);
        let n = g.usize_in(8, 35);
        let r = g.usize_in(1, 4.min(m.min(n)));
        let x = g.mat_low_rank(m, n, r);
        let bs = g.usize_in(1, n + 3);
        let sketch = *g.choose(&[
            SketchKind::Uniform,
            SketchKind::Gaussian,
            SketchKind::sparse_sign(),
        ]);
        let opts = QbOptions::new(r).with_oversample(4).with_power_iters(1).with_sketch(sketch);
        let mut r1 = g.rng();
        let mut r2 = r1.clone();
        let blocked = qb_blocked(&MatSource(&x), opts, bs, &mut r1).unwrap();
        let full = qb_blocked(&MatSource(&x), opts, n, &mut r2).unwrap();
        prop_assert!(blocked.q == full.q, "block size {bs} changed Q ({sketch:?})");
        prop_assert!(blocked.b == full.b, "block size {bs} changed B ({sketch:?})");
        Ok(())
    });
}

#[test]
fn prop_sparse_sign_qb_within_constant_factor_of_gaussian() {
    // Structured sparse-sign sketches must match dense-Gaussian QB
    // quality to within a constant factor on noisy low-rank inputs
    // (OSNAP subspace-embedding guarantee; power iterations sharpen both).
    forall("sparse-sign QB ≈ Gaussian QB", 12, |g| {
        let m = g.usize_in(30, 80);
        let n = g.usize_in(25, 60);
        let r = g.usize_in(1, 4.min(m.min(n)));
        let mut x = g.mat_low_rank(m, n, r);
        let noise = g.mat_gaussian(m, n);
        x.axpy(1e-3, &noise);
        let mut r1 = g.rng();
        let mut r2 = r1.clone();
        let base = QbOptions::new(r).with_oversample(10).with_power_iters(2);
        let gauss = qb(&x, base.with_sketch(SketchKind::Gaussian), &mut r1);
        let sparse = qb(&x, base.with_sketch(SketchKind::sparse_sign()), &mut r2);
        let eg = gauss.relative_error(&x);
        let es = sparse.relative_error(&x);
        prop_assert!(
            es <= 4.0 * eg + 1e-9,
            "sparse-sign err {es} vs gaussian err {eg} (>4x)"
        );
        Ok(())
    });
}

#[test]
fn prop_csr_kernels_match_dense_oracles() {
    // Random triplet soups — duplicate coordinates allowed (must be
    // summed), rows and columns left empty at random — against naive
    // dense oracles for construction, both product kernels, and the
    // row-sum/norm helpers.
    forall("csr kernels == dense oracles", 30, |g| {
        let m = g.usize_in(1, 40);
        let n = g.usize_in(1, 30);
        let l = g.usize_in(1, 8);
        let ntrip = g.usize_in(0, 2 * m);
        let mut trips = Vec::with_capacity(ntrip);
        let mut dense = Mat::zeros(m, n);
        for _ in 0..ntrip {
            let i = g.usize_in(0, m - 1);
            let j = g.usize_in(0, n - 1);
            let v = g.f64_in(-2.0, 2.0);
            trips.push((i, j, v));
            dense.set(i, j, dense.get(i, j) + v);
        }
        let x = CsrMat::from_triplets(m, n, &trips);
        prop_assert!(x.to_dense().max_abs_diff(&dense) < 1e-12, "to_dense != oracle");
        // Sorted-column invariant holds for any input order.
        for i in 0..m {
            let (js, _) = x.row(i);
            for w in js.windows(2) {
                prop_assert!(w[0] < w[1], "row {i}: columns not strictly ascending");
            }
        }
        // Y = X·B against the naive dense product.
        let b = g.mat_gaussian(n, l);
        let mut y = Mat::zeros(m, l);
        csr_matmul_into(&x, &b, &mut y);
        let y_oracle = gemm::matmul_naive(&dense, &b);
        prop_assert!(y.max_abs_diff(&y_oracle) < 1e-10, "csr_matmul_into != naive");
        // C = Xᵀ·Q against the naive dense product (workspace reused).
        let q = g.mat_gaussian(m, l);
        let mut c = Mat::zeros(n, l);
        let mut ws = Workspace::new();
        csr_at_b_into(&x, &q, &mut c, &mut ws);
        let c_oracle = gemm::matmul_naive(&dense.transpose(), &q);
        prop_assert!(c.max_abs_diff(&c_oracle) < 1e-10, "csr_at_b_into != naive");
        let first = c.clone();
        csr_at_b_into(&x, &q, &mut c, &mut ws);
        prop_assert!(c == first, "workspace reuse not bit-identical (csr_at_b)");
        // Row helpers.
        let mut sums = vec![0.0; m];
        x.row_sums_into(&mut sums);
        for i in 0..m {
            let s: f64 = dense.row(i).iter().sum();
            prop_assert!((sums[i] - s).abs() < 1e-12, "row_sums[{i}]");
        }
        Ok(())
    });
    // Deterministic edge cases: zero-row matrix, all-duplicate triplets,
    // and a matrix whose every nonzero shares one column.
    let empty = CsrMat::from_triplets(0, 7, &[]);
    assert_eq!(empty.shape(), (0, 7));
    let mut c = Mat::zeros(7, 3);
    csr_at_b_into(&empty, &Mat::zeros(0, 3), &mut c, &mut Workspace::new());
    assert!(c.as_slice().iter().all(|&v| v == 0.0));
    let dup = CsrMat::from_triplets(2, 2, &[(1, 1, 1.0), (1, 1, 2.0), (1, 1, -3.0)]);
    assert_eq!(dup.nnz(), 1, "duplicates collapse to one stored entry");
    assert_eq!(dup.to_dense(), Mat::zeros(2, 2));
    let one_col = CsrMat::from_triplets(3, 4, &[(0, 2, 1.0), (1, 2, 2.0), (2, 2, 3.0)]);
    let mut y = Mat::zeros(3, 2);
    csr_matmul_into(&one_col, &Mat::from_fn(4, 2, |i, j| (i * 2 + j) as f64), &mut y);
    for i in 0..3 {
        assert_eq!(y.row(i), &[(i + 1) as f64 * 4.0, (i + 1) as f64 * 5.0]);
    }
}

#[test]
fn prop_csc_at_b_matches_csr() {
    // Random triplet soups: the CSC mirror must round-trip the CSR
    // exactly, and `csc_at_b_into` must bit-match the single-threaded
    // CSR scatter (same ascending-inner-index sums) and match the naive
    // dense oracle within accumulation tolerance.
    forall("csc kernels == csr/dense oracles", 30, |g| {
        let m = g.usize_in(1, 40);
        let n = g.usize_in(1, 30);
        let l = g.usize_in(1, 8);
        let ntrip = g.usize_in(0, 2 * m);
        let mut trips = Vec::with_capacity(ntrip);
        for _ in 0..ntrip {
            trips.push((g.usize_in(0, m - 1), g.usize_in(0, n - 1), g.f64_in(-2.0, 2.0)));
        }
        let x = CsrMat::from_triplets(m, n, &trips);
        let xc = CscMat::from_csr(&x);
        prop_assert!(xc.to_csr() == x, "CSR -> CSC -> CSR round trip not exact");
        prop_assert!(xc.to_dense() == x.to_dense(), "mirrors densify differently");
        // Per-column strictly ascending rows.
        for j in 0..n {
            let (is, _) = xc.col(j);
            for w in is.windows(2) {
                prop_assert!(w[0] < w[1], "col {j}: rows not strictly ascending");
            }
        }
        let q = g.mat_gaussian(m, l);
        let mut via_csr = Mat::zeros(n, l);
        csr_at_b_into(&x, &q, &mut via_csr, &mut Workspace::new());
        let mut via_csc = Mat::zeros(n, l);
        csc_at_b_into(&xc, &q, &mut via_csc);
        prop_assert!(via_csc == via_csr, "csc_at_b != csr_at_b bitwise");
        let oracle = gemm::matmul_naive(&x.to_dense().transpose(), &q);
        prop_assert!(via_csc.max_abs_diff(&oracle) < 1e-10, "csc_at_b != naive");
        Ok(())
    });
}

#[test]
fn prop_blocked_sparse_qb_bit_deterministic_across_block_sizes() {
    // The sparse out-of-core engine computes over the same fixed
    // absolute chunk grid as the dense one: a fixed seed must give
    // bit-identical factors for any I/O block size and sketch kind, and
    // (sub-KC single-chunk shapes) equal the dense blocked engine too.
    forall("sparse blocked QB bitwise == any block size", 12, |g| {
        let m = g.usize_in(8, 40);
        let n = g.usize_in(8, 35);
        let r = g.usize_in(1, 4.min(m.min(n)));
        let dense = g.mat_low_rank(m, n, r).map(|v| if v < 0.5 { 0.0 } else { v });
        let csc = CscMat::from_csr(&CsrMat::from_dense(&dense));
        let bs = g.usize_in(1, n + 3);
        let sketch = *g.choose(&[
            SketchKind::Uniform,
            SketchKind::Gaussian,
            SketchKind::sparse_sign(),
        ]);
        let opts = QbOptions::new(r).with_oversample(4).with_power_iters(1).with_sketch(sketch);
        let mut r1 = g.rng();
        let mut r2 = r1.clone();
        let mut r3 = r1.clone();
        let blocked = qb_blocked_sparse(&CscSource(&csc), opts, bs, &mut r1).unwrap();
        let full = qb_blocked_sparse(&CscSource(&csc), opts, n, &mut r2).unwrap();
        prop_assert!(blocked.q == full.q, "block size {bs} changed Q ({sketch:?})");
        prop_assert!(blocked.b == full.b, "block size {bs} changed B ({sketch:?})");
        let dense_blocked = qb_blocked(&MatSource(&dense), opts, bs, &mut r3).unwrap();
        prop_assert!(
            blocked.q == dense_blocked.q && blocked.b == dense_blocked.b,
            "sparse stream differs from dense blocked engine ({sketch:?})"
        );
        Ok(())
    });
}

#[test]
fn prop_sparse_deterministic_hals_matches_densified() {
    // The acceptance property for the deterministic sparse path: with
    // identical seeds, `Hals::fit` / `Mu::fit` on CSR or dual-storage
    // input match the densified fit within 1e-10 across update orders
    // (on these single-threaded sub-KC shapes the factors are in fact
    // bit-identical; the tolerance is slack, not a crutch).
    forall("sparse deterministic fit == densified", 8, |g| {
        let m = g.usize_in(20, 60);
        let n = g.usize_in(20, 50);
        let r = g.usize_in(1, 4);
        let density = g.f64_in(0.05, 0.4);
        let mut data_rng = g.rng();
        let xs = randnmf::data::synthetic::sparse_low_rank(m, n, r, density, &mut data_rng);
        let dual = randnmf::linalg::sparse::SparseMat::new(xs.clone());
        let xd = xs.to_dense();
        let k = g.usize_in(1, r);
        let order = *g.choose(&[UpdateOrder::BlockedCyclic, UpdateOrder::Shuffled]);
        let opts = NmfOptions::new(k)
            .with_max_iter(12)
            .with_tol(0.0)
            .with_seed(g.usize_in(0, 1 << 30) as u64)
            .with_update_order(order);
        let hals = Hals::new(opts.clone());
        let hd = hals.fit(&xd).map_err(|e| e.to_string())?;
        let hs = hals.fit(&xs).map_err(|e| e.to_string())?;
        let hu = hals.fit(&dual).map_err(|e| e.to_string())?;
        prop_assert!(hs.model.w.max_abs_diff(&hd.model.w) < 1e-10, "{order:?}: HALS W (csr)");
        prop_assert!(hs.model.h.max_abs_diff(&hd.model.h) < 1e-10, "{order:?}: HALS H (csr)");
        prop_assert!(hu.model.w.max_abs_diff(&hd.model.w) < 1e-10, "{order:?}: HALS W (dual)");
        prop_assert!(hu.model.h.max_abs_diff(&hd.model.h) < 1e-10, "{order:?}: HALS H (dual)");
        prop_assert!(
            (hs.final_rel_err - hd.final_rel_err).abs() < 1e-10,
            "{order:?}: HALS rel_err {} vs {}",
            hs.final_rel_err,
            hd.final_rel_err
        );
        let mu = Mu::new(opts);
        let md = mu.fit(&xd).map_err(|e| e.to_string())?;
        let ms = mu.fit(&dual).map_err(|e| e.to_string())?;
        prop_assert!(ms.model.w.max_abs_diff(&md.model.w) < 1e-10, "MU W (dual)");
        prop_assert!(ms.model.h.max_abs_diff(&md.model.h) < 1e-10, "MU H (dual)");
        Ok(())
    });
}

#[test]
fn prop_sparse_fit_matches_densified_fit() {
    // The acceptance property: identical RNG draw order means a sparse
    // fit must reproduce the fit of the densified same matrix within
    // 1e-10 (on these small single-threaded shapes the compression stage
    // is in fact bit-identical — see the sparse module docs — so the
    // factors agree exactly; the tolerance is slack, not a crutch).
    forall("sparse fit == densified fit", 8, |g| {
        let m = g.usize_in(20, 60);
        let n = g.usize_in(20, 50);
        let r = g.usize_in(1, 4);
        let density = g.f64_in(0.05, 0.4);
        let mut data_rng = g.rng();
        let xs = randnmf::data::synthetic::sparse_low_rank(m, n, r, density, &mut data_rng);
        let xd = xs.to_dense();
        let k = g.usize_in(1, r);
        let sketch = *g.choose(&[
            SketchKind::Uniform,
            SketchKind::Gaussian,
            SketchKind::sparse_sign(),
        ]);
        let opts = NmfOptions::new(k)
            .with_max_iter(10)
            .with_tol(0.0)
            .with_seed(g.usize_in(0, 1 << 30) as u64)
            .with_oversample(4)
            .with_sketch(sketch);
        let solver = RandomizedHals::new(opts);
        let fs = solver
            .fit_with(&xs, &mut RhalsScratch::new())
            .map_err(|e| e.to_string())?;
        let fd = solver
            .fit_with(&xd, &mut RhalsScratch::new())
            .map_err(|e| e.to_string())?;
        let dw = fs.model.w.max_abs_diff(&fd.model.w);
        let dh = fs.model.h.max_abs_diff(&fd.model.h);
        prop_assert!(dw < 1e-10, "{sketch:?}: W diff {dw}");
        prop_assert!(dh < 1e-10, "{sketch:?}: H diff {dh}");
        prop_assert!(
            (fs.final_rel_err - fd.final_rel_err).abs() < 1e-10,
            "{sketch:?}: rel_err {} vs {}",
            fs.final_rel_err,
            fd.final_rel_err
        );
        Ok(())
    });
}

#[test]
fn prop_sweep_preserves_nonnegativity_any_regularization() {
    forall("sweep keeps F >= 0", 40, |g| {
        let r = g.usize_in(1, 50);
        let k = g.usize_in(1, 8);
        let mut fac = g.mat(r, k);
        let num = g.mat_gaussian(r, k); // adversarial numerators
        let other = g.mat(k.max(2) * 2, k);
        let gram = gemm::gram(&other);
        let reg = Regularization::elastic_net(g.f64_in(0.0, 2.0), g.f64_in(0.0, 2.0));
        let order: Vec<usize> = (0..k).collect();
        sweep_factor(&mut fac, &num, &gram, reg, &order, true);
        prop_assert!(fac.is_nonneg(), "negativity leaked");
        prop_assert!(!fac.has_non_finite(), "non-finite values");
        Ok(())
    });
}

#[test]
fn prop_hals_objective_never_increases() {
    forall("HALS monotone", 8, |g| {
        let m = g.usize_in(15, 40);
        let n = g.usize_in(15, 35);
        let r = g.usize_in(2, 4);
        let x = g.mat_low_rank(m, n, r);
        let k = g.usize_in(1, r + 2);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let fit = Hals::new(
            NmfOptions::new(k).with_max_iter(25).with_seed(seed).with_trace_every(1),
        )
        .fit(&x)
        .map_err(|e| e.to_string())?;
        for w in fit.trace.windows(2) {
            prop_assert!(
                w[1].rel_err <= w[0].rel_err + 1e-9,
                "objective rose {} -> {}",
                w[0].rel_err,
                w[1].rel_err
            );
        }
        Ok(())
    });
}

#[test]
fn prop_rhals_factors_always_feasible() {
    forall("rHALS feasible under all options", 10, |g| {
        let m = g.usize_in(20, 60);
        let n = g.usize_in(20, 50);
        let x = g.mat_low_rank(m, n, 3);
        let k = g.usize_in(1, 4);
        let order = *g.choose(&[UpdateOrder::BlockedCyclic, UpdateOrder::Shuffled]);
        let batched = g.bool();
        let opts = NmfOptions::new(k)
            .with_max_iter(15)
            .with_seed(g.usize_in(0, 1 << 30) as u64)
            .with_oversample(g.usize_in(1, 10))
            .with_power_iters(g.usize_in(0, 2))
            .with_update_order(order)
            .with_batched_projection(batched);
        let fit = RandomizedHals::new(opts).fit(&x).map_err(|e| e.to_string())?;
        prop_assert!(fit.model.w.is_nonneg(), "W negative");
        prop_assert!(fit.model.h.is_nonneg(), "H negative");
        prop_assert!(!fit.model.w.has_non_finite(), "W non-finite");
        prop_assert!(fit.final_rel_err.is_finite(), "error non-finite");
        Ok(())
    });
}

#[test]
fn prop_store_roundtrip_any_block() {
    forall("store roundtrip", 20, |g| {
        let rows = g.usize_in(1, 30);
        let cols = g.usize_in(1, 40);
        let block = g.usize_in(1, cols + 5);
        let m = g.mat(rows, cols);
        let dir = std::env::temp_dir().join("randnmf_prop_store");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("p{rows}x{cols}b{block}.nmfstore"));
        randnmf::data::store::write_mat(&path, &m, block).map_err(|e| e.to_string())?;
        let store = randnmf::data::store::NmfStore::open(&path).map_err(|e| e.to_string())?;
        let back = store.read_all().map_err(|e| e.to_string())?;
        prop_assert!(back == m, "roundtrip mismatch");
        Ok(())
    });
}

#[test]
fn prop_config_parser_roundtrips_generated_docs() {
    forall("config parse", 30, |g| {
        use randnmf::coordinator::config::Config;
        // Generate a random but valid document.
        let nsec = g.usize_in(1, 3);
        let mut doc = String::new();
        let mut expected: Vec<(String, String, i64)> = Vec::new();
        for s in 0..nsec {
            doc.push_str(&format!("[sec{s}]\n"));
            let nkeys = g.usize_in(0, 4);
            for kidx in 0..nkeys {
                let v = g.usize_in(0, 1000) as i64;
                doc.push_str(&format!("key{kidx} = {v} # comment\n"));
                expected.push((format!("sec{s}"), format!("key{kidx}"), v));
            }
        }
        let cfg = Config::parse(&doc).map_err(|e| e.to_string())?;
        for (sec, key, v) in expected {
            prop_assert!(
                cfg.get(&sec, &key).and_then(|x| x.as_i64()) == Some(v),
                "lost {sec}.{key}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_transform_matches_pinned_fit() {
    // The serving path IS the pinned-W HALS H-step: for any basis, batch
    // (dense and its CSR mirror), update order, sweep count and seed,
    // `Transform::transform_with` must **bit-match** a hand-rolled fit
    // that freezes W — same `input_at_b_into` numerator, same diag-scaled
    // init, same `sweep_factor` calls under the same `OrderState` draws.
    forall("transform == pinned-W fit (bitwise)", 10, |g| {
        let m = g.usize_in(10, 50);
        let k = g.usize_in(1, 6);
        let b = g.usize_in(1, 20);
        let w = g.mat(m, k).map(|v| v + 0.05);
        let dense = g.mat(m, b).map(|v| if v < 0.4 { 0.0 } else { v });
        let csr = CsrMat::from_dense(&dense);
        let sweeps = g.usize_in(5, 40);
        let order = *g.choose(&[UpdateOrder::BlockedCyclic, UpdateOrder::Shuffled]);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let topts = TransformOptions::default()
            .with_sweeps(sweeps)
            .with_order(order)
            .with_seed(seed);
        let t = Transform::new(w.clone(), topts).map_err(|e| e.to_string())?;
        let mut scratch = TransformScratch::new();
        let mut ws = Workspace::new();
        let gram = gemm::gram(&w);

        for sparse_input in [false, true] {
            // Pinned-fit oracle from the same primitives, same sequence.
            let input: NmfInput = if sparse_input { (&csr).into() } else { (&dense).into() };
            let mut num = Mat::zeros(b, k);
            input_at_b_into(input, &w, &mut num, &mut ws);
            let mut ct = Mat::zeros(b, k);
            for r in 0..b {
                for j in 0..k {
                    let d = gram.get(j, j).max(1e-12);
                    ct.set(r, j, (num.get(r, j) / d).max(0.0));
                }
            }
            let mut ord = OrderState::new(k, order);
            let mut rng = Pcg64::seed_from_u64(seed);
            for _ in 0..sweeps {
                ord.advance(&mut rng);
                sweep_factor(&mut ct, &num, &gram, Regularization::NONE, ord.order(), true);
            }
            let oracle = ct.transpose();

            let h = if sparse_input {
                t.transform_with(&csr, &mut scratch)
            } else {
                t.transform_with(&dense, &mut scratch)
            }
            .map_err(|e| e.to_string())?;
            prop_assert!(
                h == oracle,
                "{order:?} sparse={sparse_input}: transform != pinned fit (max diff {})",
                h.max_abs_diff(&oracle)
            );
            prop_assert!(h.is_nonneg(), "H negative");
            scratch.recycle(h);
        }
        Ok(())
    });
}

#[test]
fn prop_transform_kkt_stationarity_at_convergence() {
    // Run the pinned solve until the inner iteration goes quiet, then
    // check the NNLS first-order (KKT) conditions of
    // min_{H≥0} ½‖X − WH‖²: with G = WᵀW·H − WᵀX, every positive entry
    // must have |G| ≈ 0 and every zero entry must have G ≥ 0, to 1e-8
    // relative to the numerator scale.
    forall("converged transform satisfies KKT to 1e-8", 8, |g| {
        let m = g.usize_in(10, 40);
        let k = g.usize_in(1, 5);
        let b = g.usize_in(1, 10);
        // Boost one distinct row per column: keeps the Gram's condition
        // number bounded, so coordinate descent actually reaches 1e-8
        // stationarity within the sweep budget for every drawn basis.
        let mut w = g.mat(m, k).map(|v| v + 0.05);
        for j in 0..k {
            w.set(j, j, w.get(j, j) + 2.0);
        }
        let x = g.mat(m, b);
        let topts = TransformOptions::default().with_sweeps(4000).with_inner_tol(1e-15);
        let t = Transform::new(w.clone(), topts).map_err(|e| e.to_string())?;
        let h = t.transform(&x).map_err(|e| e.to_string())?;
        let gram = gemm::gram(&w);
        let num = gemm::at_b(&w, &x); // k×b (WᵀX)
        let grad = gemm::matmul(&gram, &h).sub(&num);
        let scale = num.as_slice().iter().fold(1.0f64, |a, v| a.max(v.abs()));
        let tol = 1e-8 * scale;
        for i in 0..k {
            for j in 0..b {
                let gij = grad.get(i, j);
                if h.get(i, j) > 0.0 {
                    prop_assert!(gij.abs() <= tol, "interior grad {gij} at ({i},{j})");
                } else {
                    prop_assert!(gij >= -tol, "active-set grad {gij} at ({i},{j})");
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_online_fit_matches_batch() {
    // Streaming pushes + one refresh must be bit-deterministic in the
    // chunking (any two chunk sizes give identical factors), and land at
    // the same reconstruction quality as the batch randomized fit of the
    // concatenated matrix (the two compress with differently-ordered
    // accumulations, so factors agree in quality, not bitwise — the
    // documented tolerance is 5e-2 on exactly low-rank data).
    forall("online fit == batch fit (chunking-invariant)", 6, |g| {
        let m = g.usize_in(20, 50);
        let n = g.usize_in(30, 80);
        let r = g.usize_in(2, 4);
        let x = g.mat_low_rank(m, n, r);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let opts = NmfOptions::new(r)
            .with_max_iter(30)
            .with_tol(0.0)
            .with_seed(seed)
            .with_oversample(4);
        let c1 = g.usize_in(1, n);
        let c2 = g.usize_in(1, n);
        let run = |chunk: usize| -> Result<(Mat, Mat, f64), String> {
            let mut online = OnlineNmf::new(m, opts.clone()).map_err(|e| e.to_string())?;
            let mut j0 = 0;
            while j0 < n {
                let j1 = (j0 + chunk).min(n);
                online.push_columns(&x.col_block(j0, j1)).map_err(|e| e.to_string())?;
                j0 = j1;
            }
            let fit = online.refresh().map_err(|e| e.to_string())?;
            let err = norms::relative_error(&x, &fit.model.w, &fit.model.h);
            Ok((fit.model.w.clone(), fit.model.h.clone(), err))
        };
        let (w1, h1, e1) = run(c1)?;
        let (w2, h2, _) = run(c2)?;
        prop_assert!(w1 == w2, "chunk {c1} vs {c2} changed W");
        prop_assert!(h1 == h2, "chunk {c1} vs {c2} changed H");
        let batch = RandomizedHals::new(opts).fit(&x).map_err(|e| e.to_string())?;
        let eb = norms::relative_error(&x, &batch.model.w, &batch.model.h);
        prop_assert!((e1 - eb).abs() < 5e-2, "online err {e1} vs batch err {eb}");
        Ok(())
    });
}

#[test]
fn prop_srht_apply_matches_padded_wht_oracle() {
    // The fast SRHT apply against an explicitly staged padded-WHT oracle,
    // **bitwise**. The oracle evaluates the transform recursively
    // (halves, then one stride-n/2 combine); the production kernel runs
    // the iterative LSB-first butterflies — same per-element operation
    // DAG, so outputs must agree bit for bit on these sub-KC
    // single-threaded shapes (and the draw-order contract means a cloned
    // RNG re-draws the exact tables).
    fn recursive_wht(buf: &mut [f64]) {
        let n = buf.len();
        if n <= 1 {
            return;
        }
        let h = n / 2;
        let (lo, hi) = buf.split_at_mut(h);
        recursive_wht(lo);
        recursive_wht(hi);
        for i in 0..h {
            let x = lo[i];
            let y = hi[i];
            lo[i] = x + y;
            hi[i] = x - y;
        }
    }
    forall("srht apply == padded WHT oracle (bitwise)", 25, |g| {
        let m = g.usize_in(1, 20);
        let n = g.usize_in(1, 40);
        let l = g.usize_in(1, 8.min(n));
        let x = g.mat_gaussian(m, n);
        let n_pad = srht::padded_len(n);
        let mut ws = Workspace::new();
        let mut y = Mat::zeros(m, l);
        let mut rng = g.rng();
        let mut rng_oracle = rng.clone();
        srht::srht_sketch_apply(NmfInput::Dense(&x), l, &mut rng, &mut y, &mut ws);
        // Oracle: re-draw the tables from the cloned RNG, then stage each
        // sign-flipped zero-padded row and transform it recursively.
        let mut signs = vec![0.0; n];
        let mut samples = vec![0.0; l];
        srht::fill_srht(&mut rng_oracle, n_pad, &mut signs, &mut samples);
        let scale = 1.0 / (l as f64).sqrt();
        let mut want = Mat::zeros(m, l);
        let mut stage = vec![0.0; n_pad];
        for i in 0..m {
            stage.fill(0.0);
            for (r, s) in stage[..n].iter_mut().enumerate() {
                *s = x.get(i, r) * signs[r];
            }
            recursive_wht(&mut stage);
            for t in 0..l {
                want.set(i, t, stage[samples[t] as usize] * scale);
            }
        }
        prop_assert!(
            y == want,
            "{m}x{n} l={l}: fast SRHT apply != recursive-WHT oracle (max diff {})",
            y.max_abs_diff(&want)
        );
        Ok(())
    });
}

#[test]
fn prop_twosided_within_constant_factor_of_rhals() {
    // The acceptance property for the two-sided solver: on noisy
    // low-rank data its final relative error must stay within a constant
    // factor of one-sided randomized HALS — the column-compressed W
    // numerator replaces the exact X·Hᵀ, and with oversampling + power
    // iterations the left projection's tail loss is of the same order as
    // the right's (see docs/COMPRESSION.md).
    forall("two-sided err ≤ C · one-sided err", 8, |g| {
        let m = g.usize_in(30, 80);
        let n = g.usize_in(25, 60);
        let r = g.usize_in(1, 4.min(m.min(n)));
        let mut x = g.mat_low_rank(m, n, r);
        let noise = g.mat_gaussian(m, n).map(|v| v.abs());
        x.axpy(1e-3, &noise);
        let sketch = *g.choose(&[SketchKind::Uniform, SketchKind::Srht]);
        let opts = NmfOptions::new(r)
            .with_max_iter(60)
            .with_tol(0.0)
            .with_seed(g.usize_in(0, 1 << 30) as u64)
            .with_oversample(8)
            .with_power_iters(2)
            .with_sketch(sketch);
        let one = RandomizedHals::new(opts.clone()).fit(&x).map_err(|e| e.to_string())?;
        let two = TwoSidedHals::new(opts).fit(&x).map_err(|e| e.to_string())?;
        prop_assert!(two.model.w.is_nonneg() && two.model.h.is_nonneg(), "infeasible factors");
        prop_assert!(
            two.final_rel_err <= 3.0 * one.final_rel_err + 1e-6,
            "{sketch:?}: twosided err {} vs rhals err {} (>3x)",
            two.final_rel_err,
            one.final_rel_err
        );
        Ok(())
    });
}

#[test]
fn prop_relative_error_factored_matches_explicit() {
    forall("factored rel-err oracle", 25, |g| {
        let m = g.usize_in(2, 30);
        let n = g.usize_in(2, 30);
        let k = g.usize_in(1, 6);
        let x = g.mat(m, n);
        let w = g.mat(m, k);
        let h = g.mat(k, n);
        let fast = norms::relative_error(&x, &w, &h);
        let slow = norms::relative_error_explicit(&x, &w, &h);
        prop_assert!((fast - slow).abs() < 1e-8, "fast {fast} slow {slow}");
        Ok(())
    });
}

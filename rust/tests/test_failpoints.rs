//! Fault-injection sweeps over every hardened I/O path (`--features
//! failpoints` only).
//!
//! The contract: with the registry armed, each operation either succeeds
//! with a result **bit-identical** to the clean run, or fails with a
//! typed, classifiable error — never a panic, and never silently-consumed
//! corrupt data. Injected in-flight bit flips must heal through the
//! CRC-triggered corrupt retry; EINTR and short reads must be absorbed
//! invisibly; transient faults must be retried up to the bounded budget.
//!
//! Every schedule is seed-driven, so a failing seed replays exactly.

#![cfg(feature = "failpoints")]

use std::path::PathBuf;

use randnmf::data::robust::{self, FaultKind};
use randnmf::data::store::{write_csc, write_mat, NmfStore, SparseNmfStore};
use randnmf::linalg::rng::Pcg64;
use randnmf::linalg::sparse::{CscMat, CsrMat};
use randnmf::nmf::hals::Hals;
use randnmf::nmf::model::NmfModel;
use randnmf::nmf::options::NmfOptions;
use randnmf::nmf::persist;
use randnmf::testing::failpoints::{FailpointConfig, Session};

fn dir(sub: &str) -> PathBuf {
    let d = std::env::temp_dir().join("randnmf_failpoints").join(sub);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Faults on the read path only (write probability zeroed).
fn read_faults(p: f64) -> FailpointConfig {
    FailpointConfig { p_transient_write: 0.0, ..FailpointConfig::all(p) }
}

/// A failure under injection must carry a non-fatal classification — the
/// injected classes are transient and corrupt, and the retry wrapper must
/// preserve the marker even after giving up.
fn assert_injected(err: &anyhow::Error) {
    assert_ne!(
        robust::classify(err),
        FaultKind::Fatal,
        "injected fault surfaced untyped: {err}"
    );
}

#[test]
fn dense_store_reads_survive_failpoint_injection() {
    let mut rng = Pcg64::seed_from_u64(1);
    let x = rng.uniform_mat(19, 37);
    let path = dir("dense").join("reads.nmfstore");
    write_mat(&path, &x, 7).unwrap();

    let (mut ok, mut faults) = (0u32, 0u64);
    for seed in 0..40u64 {
        let fp = Session::arm(seed, read_faults(0.06));
        let r = NmfStore::open(&path).and_then(|s| {
            s.verify_integrity()?;
            s.read_all()
        });
        faults += fp.hits();
        drop(fp);
        match r {
            Ok(y) => {
                assert_eq!(y, x, "seed {seed}: injected read returned wrong data");
                ok += 1;
            }
            Err(e) => assert_injected(&e),
        }
    }
    assert!(faults > 0, "injection never fired");
    assert!(ok > 0, "no seed survived — the retry policy is not absorbing faults");
}

#[test]
fn dense_store_writes_survive_failpoint_injection() {
    let mut rng = Pcg64::seed_from_u64(2);
    let x = rng.uniform_mat(23, 18);
    let path = dir("dense").join("writes.nmfstore");

    let (mut ok, mut faults) = (0u32, 0u64);
    for seed in 0..30u64 {
        let cfg = FailpointConfig { p_transient_write: 0.15, ..Default::default() };
        let fp = Session::arm(seed, cfg);
        let r = write_mat(&path, &x, 6);
        faults += fp.hits();
        drop(fp);
        match r {
            Ok(()) => {
                // Whatever the write endured, the published file is whole.
                let back = NmfStore::open(&path).unwrap();
                back.verify_integrity().unwrap();
                assert_eq!(back.read_all().unwrap(), x, "seed {seed}: torn write published");
                ok += 1;
            }
            Err(e) => assert_injected(&e),
        }
    }
    assert!(faults > 0, "injection never fired");
    assert!(ok > 0, "no write survived the transient-retry budget");
}

#[test]
fn sparse_store_failpoint_injection_roundtrip() {
    let mut rng = Pcg64::seed_from_u64(3);
    let mut dense = rng.uniform_mat(21, 16);
    for v in dense.as_mut_slice().iter_mut() {
        if *v < 0.7 {
            *v = 0.0;
        }
    }
    let csc = CscMat::from_csr(&CsrMat::from_dense(&dense));
    let path = dir("sparse").join("roundtrip.nmfsparse");

    let (mut ok, mut faults) = (0u32, 0u64);
    for seed in 0..30u64 {
        let fp = Session::arm(seed, FailpointConfig::all(0.05));
        let r = write_csc(&path, &csc, 5).and_then(|()| {
            let s = SparseNmfStore::open(&path)?;
            s.verify_integrity()?;
            s.read_all()
        });
        faults += fp.hits();
        drop(fp);
        match r {
            Ok(back) => {
                assert!(back == csc, "seed {seed}: injected round-trip returned wrong data");
                ok += 1;
            }
            Err(e) => assert_injected(&e),
        }
    }
    assert!(faults > 0, "injection never fired");
    assert!(ok > 0, "no seed survived the sparse round-trip");
}

#[test]
fn model_persist_failpoint_injection() {
    let mut rng = Pcg64::seed_from_u64(4);
    let model = NmfModel { w: rng.uniform_mat(14, 3), h: rng.uniform_mat(3, 11) };
    let path = dir("persist").join("model.nmfmodel");
    persist::save(&path, &model).unwrap();

    let (mut ok, mut faults) = (0u32, 0u64);
    for seed in 0..40u64 {
        let fp = Session::arm(seed, read_faults(0.06));
        let r = persist::load(&path);
        faults += fp.hits();
        drop(fp);
        match r {
            Ok(back) => {
                assert_eq!(back.w, model.w, "seed {seed}: W corrupted in flight");
                assert_eq!(back.h, model.h, "seed {seed}: H corrupted in flight");
                ok += 1;
            }
            Err(e) => assert_injected(&e),
        }
    }
    assert!(faults > 0, "injection never fired");
    assert!(ok > 0, "no load survived injection");
}

/// Checkpoint writes under injection either publish a whole checkpoint
/// (resume is then bit-identical to the uninterrupted fit) or fail typed;
/// a resume under read injection heals or fails typed — never diverges.
#[test]
fn checkpoint_write_and_resume_survive_failpoint_injection() {
    let mut rng = Pcg64::seed_from_u64(5);
    let x = {
        let u = rng.uniform_mat(30, 3);
        let v = rng.uniform_mat(3, 24);
        randnmf::linalg::gemm::matmul(&u, &v)
    };
    let base = NmfOptions::new(3).with_seed(21).with_tol(0.0).with_trace_every(2);
    let uninterrupted = Hals::new(base.clone().with_max_iter(9)).fit(&x).unwrap();
    let path = dir("ckpt").join("inject.nmfckpt");

    let (mut ok, mut faults) = (0u32, 0u64);
    for seed in 0..12u64 {
        std::fs::remove_file(&path).ok();

        // Interrupted fit with checkpoint writes under write injection.
        let cfg = FailpointConfig { p_transient_write: 0.1, ..Default::default() };
        let fp = Session::arm(seed, cfg);
        let r = Hals::new(base.clone().with_max_iter(5).with_checkpoint(&path, 1)).fit(&x);
        faults += fp.hits();
        drop(fp);
        if let Err(e) = r {
            assert_injected(&e);
            continue;
        }

        // Resume under read injection: heal or fail typed.
        let fp = Session::arm(seed.wrapping_add(1000), read_faults(0.04));
        let r = Hals::new(base.clone().with_max_iter(9).with_resume_from(&path)).fit(&x);
        faults += fp.hits();
        drop(fp);
        match r {
            Ok(resumed) => {
                assert_eq!(resumed.model.w, uninterrupted.model.w, "seed {seed}: W diverged");
                assert_eq!(resumed.model.h, uninterrupted.model.h, "seed {seed}: H diverged");
                assert_eq!(resumed.iters, uninterrupted.iters);
                ok += 1;
            }
            Err(e) => assert_injected(&e),
        }
    }
    std::fs::remove_file(&path).ok();
    assert!(faults > 0, "injection never fired");
    assert!(ok > 0, "no kill/resume cycle survived injection");
}

"""L2: the paper's compute graphs in JAX, calling the L1 Pallas kernels.

Three jit-able entry points, each lowered AOT by :mod:`compile.aot` into an
HLO-text artifact that the Rust runtime executes via PJRT:

* :func:`rhals_iteration` — one randomized-HALS iteration (Algorithm 1
  lines 12–22, batched projection). Inputs ``(b, q, w, wt, ht)``; outputs
  the updated ``(w, wt, ht)``.
* :func:`hals_iteration` — one deterministic HALS iteration (Eqs. 14–15),
  the XLA-engine baseline.
* :func:`qb_sketch` — the compression stage (Algorithm 1 lines 1–9) with
  CholeskyQR2 orthonormalization (native HLO ops only — no LAPACK
  custom-calls, so the artifact runs on the stock PJRT CPU client).

Shapes are static per artifact; the AOT driver emits one artifact per
shape variant listed in its manifest. Python never runs at serve time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.hals_update import hals_sweep
from .kernels.matmul import matmul_tiled
from .kernels.ref import DEAD_EPS, cholqr2_ref as _cholqr2


def rhals_iteration(b, q, w, wt, ht, *, l1_w=0.0, l2_w=0.0, l1_h=0.0, l2_h=0.0):
    """One randomized HALS iteration (batched projection variant).

    Args:
      b:  ``(l, n)`` compressed surrogate ``Q^T X``.
      q:  ``(m, l)`` orthonormal range basis.
      w:  ``(m, k)`` nonnegative high-dimensional basis.
      wt: ``(l, k)`` compressed basis ``Q^T W``.
      ht: ``(n, k)`` transposed coefficients.

    Returns:
      ``(w, wt, ht)`` after the iteration.
    """
    # --- H sweep (Eq. 19; high-dimensional Gram for scaling, §3.2) ---
    r = b.T @ wt                     # (n, k)
    s = w.T @ w                      # (k, k)
    ht = hals_sweep(ht, r, s, l1=l1_h, l2=l2_h, clamp=True)

    # --- W~ sweep + projection (Eqs. 20-22) ---
    t = b @ ht                       # (l, k)
    v = ht.T @ ht                    # (k, k)
    wt = hals_sweep(wt, t, v, l1=0.0, l2=l2_w, clamp=False)
    w = q @ wt                       # (m, k)
    if l1_w != 0.0:
        denom = jnp.maximum(jnp.diag(v) + l2_w, DEAD_EPS)
        w = w - l1_w / denom[None, :]
    w = jnp.maximum(w, 0.0)
    wt = q.T @ w                     # (l, k)
    return w, wt, ht


def hals_iteration(x, w, ht, *, l1_w=0.0, l2_w=0.0, l1_h=0.0, l2_h=0.0):
    """One deterministic HALS iteration (Eqs. 14-15), transposed layout."""
    s = w.T @ w
    at = x.T @ w
    ht = hals_sweep(ht, at, s, l1=l1_h, l2=l2_h, clamp=True)
    v = ht.T @ ht
    t = x @ ht
    w = hals_sweep(w, t, v, l1=l1_w, l2=l2_w, clamp=True)
    return w, ht


def qb_sketch(x, omega, *, q_iters: int = 2):
    """QB compression (Algorithm 1 lines 1-9): ``(x, omega) -> (q, b)``.

    The sketch products go through the tiled Pallas matmul; the
    orthonormalizations use CholeskyQR2 (native HLO).
    """
    y = matmul_tiled(x, omega)           # (m, l)
    for _ in range(q_iters):
        qmat = _cholqr2(y)
        z = matmul_tiled(x.T, qmat)      # (n, l)
        qz = _cholqr2(z)
        y = matmul_tiled(x, qz)
    qmat = _cholqr2(y)
    b = matmul_tiled(qmat.T, x)          # (l, n)
    return qmat, b

"""L1 Pallas kernel: tiled matrix multiply for the QB sketch.

The compression stage's dominant cost is the sketch product ``Y = X @ Omega``
(and the projection ``B = Q^T X``). On TPU this is MXU work; the kernel
below is the canonical Pallas matmul schedule:

* grid ``(M/BM, N/BN, K/BK)`` with the K dimension innermost,
* ``(BM, BK) x (BK, BN)`` VMEM tiles feeding the 128x128 MXU,
* an output tile that lives in VMEM across the K loop, zero-initialized at
  ``k == 0`` via ``pl.when`` (accumulator never round-trips to HBM).

With the default 256/256/256 tiles the three live buffers take
3 * 256KiB = 768 KiB of VMEM and each loaded element is reused 256 times —
comfortably compute-bound on the MXU (see EXPERIMENTS.md §Perf for the
arithmetic-intensity table). Lowered with ``interpret=True`` for CPU
execution; the BlockSpec schedule is what a real TPU would compile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ b_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_tiled(a, b, *, bm=256, bn=256, bk=256):
    """``a @ b`` via the tiled Pallas schedule (MXU-shaped accumulation)."""
    m, ka = a.shape
    kb, n = b.shape
    assert ka == kb, (a.shape, b.shape)
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, ka)
    pad_m = (-m) % bm
    pad_n = (-n) % bn
    pad_k = (-ka) % bk
    if pad_m or pad_k:
        a = jnp.pad(a, ((0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        b = jnp.pad(b, ((0, pad_k), (0, pad_n)))
    mp, kp = a.shape
    _, np_ = b.shape

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=True,
    )(a, b)
    return out[:m, :n] if (pad_m or pad_n) else out

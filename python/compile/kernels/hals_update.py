"""L1 Pallas kernel: the HALS coordinate sweep.

This is the paper's compute hot-spot restructured for TPU semantics (see
DESIGN.md §7 "Hardware adaptation"):

* The sweep ``for j in 1..k: fac[:,j] <- update`` has a *sequential*
  dependency over components ``j`` but is *embarrassingly parallel over
  rows* of the factor panel.
* BlockSpec therefore tiles the factor along the row dimension into
  VMEM-resident ``(BR, k)`` panels; the grid walks the panels and the
  ``j``-loop runs inside the kernel (registers/VMEM only).
* With ``BR = 256`` and ``k <= 64`` a panel is at most 64 KiB — three
  live panels (fac, num, out) fit comfortably in a TPU core's ~16 MiB
  VMEM alongside the broadcast ``k x k`` Gram tile.

The kernel is lowered with ``interpret=True`` (CPU-executable HLO); on a
real TPU the same BlockSpec schedule maps panels to the VPU lanes. The
arithmetic intensity is ``O(k)`` flops per loaded element, so for the
paper's ``k = 16..64`` the sweep is compute-bound on the VPU rather than
HBM-bound — the analysis the §Perf section of EXPERIMENTS.md records.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import DEAD_EPS

# Rows per VMEM panel. 256 x 64 x 4 B = 64 KiB per operand.
DEFAULT_BLOCK_ROWS = 256


def _sweep_kernel(num_ref, gram_ref, fac_in_ref, fac_out_ref, *, k, l1, l2, clamp):
    """Kernel body: full j-sweep over one (BR, k) panel."""
    fac = fac_in_ref[...]
    num = num_ref[...]
    gram = gram_ref[...]

    def body(j, fac):
        gcol = jax.lax.dynamic_slice(gram, (0, j), (k, 1))  # (k, 1)
        gjj = jax.lax.dynamic_slice(gram, (j, j), (1, 1))[0, 0]
        facj = jax.lax.dynamic_slice(fac, (0, j), (fac.shape[0], 1))[:, 0]
        numj = jax.lax.dynamic_slice(num, (0, j), (num.shape[0], 1))[:, 0]
        cross = (fac @ gcol)[:, 0] - gjj * facj
        val = (l2 * facj + numj - l1 - cross) / (gjj + l2)
        if clamp:
            val = jnp.maximum(val, 0.0)
        val = jnp.where(gjj < DEAD_EPS, facj, val)
        return jax.lax.dynamic_update_slice(fac, val[:, None], (0, j))

    fac = jax.lax.fori_loop(0, k, body, fac)
    fac_out_ref[...] = fac


@functools.partial(
    jax.jit, static_argnames=("l1", "l2", "clamp", "block_rows")
)
def hals_sweep(fac, num, gram, *, l1=0.0, l2=0.0, clamp=True,
               block_rows=DEFAULT_BLOCK_ROWS):
    """One HALS coordinate sweep over a tall-skinny ``(r, k)`` factor panel.

    Drop-in Pallas twin of :func:`..kernels.ref.hals_sweep_ref`; the grid
    parallelizes over row panels, the sequential component loop runs
    in-kernel.
    """
    r, k = fac.shape
    assert num.shape == (r, k), (num.shape, (r, k))
    assert gram.shape == (k, k)
    br = min(block_rows, r)
    # Pad rows so the grid divides evenly; padded rows sweep garbage that
    # is sliced away (they cannot contaminate real rows: rows independent).
    pad = (-r) % br
    if pad:
        fac = jnp.pad(fac, ((0, pad), (0, 0)))
        num = jnp.pad(num, ((0, pad), (0, 0)))
    rp = fac.shape[0]

    out = pl.pallas_call(
        functools.partial(_sweep_kernel, k=k, l1=l1, l2=l2, clamp=clamp),
        grid=(rp // br,),
        in_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),  # num panel
            pl.BlockSpec((k, k), lambda i: (0, 0)),   # gram broadcast
            pl.BlockSpec((br, k), lambda i: (i, 0)),  # fac panel
        ],
        out_specs=pl.BlockSpec((br, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, k), fac.dtype),
        interpret=True,
    )(num, gram, fac)
    return out[:r] if pad else out

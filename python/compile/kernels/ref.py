"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written in
plain `jax.numpy` with no Pallas involvement. The pytest suite asserts
`assert_allclose(kernel(...), ref(...))` across shape/seed sweeps — this is
the core L1 correctness signal, mirroring the Rust-side oracle tests
(`matmul` vs `matmul_naive`, etc.).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

DEAD_EPS = 1e-12


def hals_sweep_ref(fac, num, gram, *, l1=0.0, l2=0.0, clamp=True):
    """One HALS coordinate sweep over a tall-skinny factor panel.

    Mirrors `randnmf::nmf::hals::sweep_factor` exactly (paper Eqs. 14/15
    with the regularized Eqs. 30/31/33/34):

        fac[:,j] <- [ (l2*fac[:,j] + num[:,j] - l1 - sum_{i!=j} G[i,j]*fac[:,i])
                      / (G[j,j] + l2) ]_+

    The j-loop is sequential (components couple through `fac`); rows are
    independent.
    """
    fac = jnp.asarray(fac)
    num = jnp.asarray(num)
    gram = jnp.asarray(gram)
    k = fac.shape[1]
    for j in range(k):
        gjj = gram[j, j]
        cross = fac @ gram[:, j] - gjj * fac[:, j]
        val = (l2 * fac[:, j] + num[:, j] - l1 - cross) / (gjj + l2)
        if clamp:
            val = jnp.maximum(val, 0.0)
        val = jnp.where(gjj < DEAD_EPS, fac[:, j], val)
        fac = fac.at[:, j].set(val)
    return fac


def matmul_ref(a, b):
    """Plain dense product (oracle for the tiled Pallas matmul)."""
    return jnp.asarray(a) @ jnp.asarray(b)


def hals_iteration_ref(x, w, ht, *, l1_w=0.0, l2_w=0.0, l1_h=0.0, l2_h=0.0):
    """One full deterministic HALS iteration (paper Eqs. 14-15), in the
    transposed layout used throughout (`ht : n x k`)."""
    s = w.T @ w
    at = x.T @ w
    ht = hals_sweep_ref(ht, at, s, l1=l1_h, l2=l2_h, clamp=True)
    v = ht.T @ ht
    t = x @ ht
    w = hals_sweep_ref(w, t, v, l1=l1_w, l2=l2_w, clamp=True)
    return w, ht


def rhals_iteration_ref(b, q, w, wt, ht, *, l1_w=0.0, l2_w=0.0, l1_h=0.0, l2_h=0.0):
    """One randomized HALS iteration (paper Algorithm 1 lines 12-22) with
    the batched projection variant: sweep W~ unclamped, then
    W = [Q W~ - shrink]_+ and W~ = Q^T W."""
    r = b.T @ wt
    s = w.T @ w
    ht = hals_sweep_ref(ht, r, s, l1=l1_h, l2=l2_h, clamp=True)
    t = b @ ht
    v = ht.T @ ht
    wt = hals_sweep_ref(wt, t, v, l1=0.0, l2=l2_w, clamp=False)
    w = q @ wt
    if l1_w != 0.0:
        denom = jnp.maximum(jnp.diag(v) + l2_w, DEAD_EPS)
        w = w - l1_w / denom[None, :]
    w = jnp.maximum(w, 0.0)
    wt = q.T @ w
    return w, wt, ht


def chol_pure(a, floor=1e-30):
    """Cholesky factorization built from native HLO ops only.

    `jnp.linalg.cholesky` lowers to the LAPACK custom-call `lapack_spotrf`
    on the CPU platform, which the xla_extension 0.5.1 runtime behind the
    Rust `xla` crate cannot resolve. This column-by-column `fori_loop`
    formulation lowers to a plain While loop over dynamic slices — pure
    HLO, runnable on any PJRT backend. The factor is `k x k` with
    `k = l <= 64`, so the sequential loop is negligible next to the sketch
    GEMMs.

    `floor` guards the pivot `a_jj - s_j`: on (numerically) rank-deficient
    Grams the f32 subtraction cancels catastrophically, the trailing block
    goes indefinite, and an unguarded Cholesky amplifies the error until
    later Grams overflow. When the pivot falls below `floor` the column is
    treated as **dead**: its diagonal is set to a huge scale-tied value and
    its off-diagonals to zero, so the subsequent triangular solve returns a
    ~zero basis column for that direction (exactly the rank-revealing
    behaviour QB wants) and later columns see no contamination. Callers
    pass a floor tied to the Gram's scale (the Tikhonov shift).
    """
    a = jnp.asarray(a)
    n = a.shape[0]
    idx = jnp.arange(n)
    floor = jnp.asarray(floor, a.dtype)
    # Finite "infinity": big enough that solved columns vanish, small
    # enough that its square stays representable in f32.
    big = jnp.sqrt(jnp.maximum(jnp.trace(a), 1.0)) * jnp.asarray(1e8, a.dtype)

    def body(j, l):
        row_j = jax.lax.dynamic_slice(l, (j, 0), (1, n))[0]       # L[j, :]
        s = l @ row_j                                             # sum_{p<j} L[i,p]L[j,p]
        sj = jax.lax.dynamic_slice(s, (j,), (1,))[0]
        ajj = jax.lax.dynamic_slice(a, (j, j), (1, 1))[0, 0]
        piv = ajj - sj
        dead = piv < floor
        d = jnp.sqrt(jnp.maximum(piv, floor))
        acol = jax.lax.dynamic_slice(a, (0, j), (n, 1))[:, 0]
        col = (acol - s) / d
        col = jnp.where(idx > j, col, 0.0)
        col = jnp.where(dead, jnp.zeros_like(col), col)
        col = jnp.where(idx == j, jnp.where(dead, big, d), col)
        return jax.lax.dynamic_update_slice(l, col[:, None], (0, j))

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(a))


def solve_y_lt_pure(y, l):
    """Solve `Q L^T = Y` for `Q` (right triangular solve) with native HLO:
    forward substitution over the `l` columns, each an `O(m·l)` update."""
    y = jnp.asarray(y)
    n = y.shape[1]

    def body(j, q):
        lrow = jax.lax.dynamic_slice(l, (j, 0), (1, n))[0]        # L[j, :]
        s = q @ lrow                                              # Σ_{p<j} Q[:,p]L[j,p]
        ljj = jax.lax.dynamic_slice(l, (j, j), (1, 1))[0, 0]
        ycol = jax.lax.dynamic_slice(y, (0, j), (y.shape[0], 1))[:, 0]
        col = (ycol - s) / ljj
        return jax.lax.dynamic_update_slice(q, col[:, None], (0, j))

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(y))


def cholqr2_ref(y):
    """Orthonormalization via two rounds of Cholesky-QR over the pure-HLO
    kernels above.

    The Gram is Tikhonov-shifted (`G + εI`) so rank-deficient sketches stay
    factorizable; directions beyond the numerical rank come out as
    near-zero columns (harmless for QB: they contribute nothing to `QB`,
    and randomized HALS treats them as dead components)."""

    def one(y):
        g = y.T @ y
        eps = 1e-6 * jnp.trace(g) / max(y.shape[1], 1) + 1e-30
        l = chol_pure(g + eps * jnp.eye(y.shape[1], dtype=y.dtype), floor=eps)
        return solve_y_lt_pure(y, l)

    q = one(y)
    return one(q)


def qb_sketch_ref(x, omega, q_iters: int):
    """QB decomposition (paper Algorithm 1 lines 1-9) with CholeskyQR2
    orthonormalization and `q_iters` stabilized subspace iterations."""
    y = x @ omega
    for _ in range(q_iters):
        q = cholqr2_ref(y)
        z = x.T @ q
        qz = cholqr2_ref(z)
        y = x @ qz
    q = cholqr2_ref(y)
    b = q.T @ x
    return q, b

"""L1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from . import hals_update, matmul, ref  # noqa: F401

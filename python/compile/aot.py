"""AOT lowering: JAX/Pallas (L2/L1) -> HLO text artifacts for the Rust runtime.

HLO *text* — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids, which the xla_extension
0.5.1 runtime behind the ``xla`` crate rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly
(see /opt/xla-example/README.md).

Each artifact is one jitted function at one concrete shape. The manifest
(``artifacts/manifest.json``) records op name, shapes, dtype and file so
the Rust ``runtime::registry`` can discover what exists.

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Idempotent: `make artifacts` skips the build when inputs are unchanged.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


# ---------------------------------------------------------------------------
# Shape variants.
#
# One artifact per (op, shape). The variants cover the shapes the examples
# and the engine-comparison bench run through the XLA engine; anything else
# falls back to the pure-Rust CpuEngine (runtime::registry handles the
# dispatch). Keeping this list short keeps `make artifacts` fast.
# ---------------------------------------------------------------------------

# (name, m, n, k, l, q_iters)
RHALS_VARIANTS = [
    ("demo", 2000, 1000, 16, 36, 2),
    ("quickstart", 500, 400, 8, 28, 2),
]

HALS_VARIANTS = [
    ("demo", 2000, 1000, 16),
    ("quickstart", 500, 400, 8),
]

QB_VARIANTS = [
    ("demo", 2000, 1000, 36, 2),
    ("quickstart", 500, 400, 28, 2),
]


def build_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []

    for tag, m, n, k, l, q_iters in RHALS_VARIANTS:
        fn = jax.jit(model.rhals_iteration)
        lowered = fn.lower(
            spec(l, n), spec(m, l), spec(m, k), spec(l, k), spec(n, k)
        )
        fname = f"rhals_iter_{m}x{n}_k{k}_l{l}.hlo.txt"
        _write(out_dir, fname, to_hlo_text(lowered))
        entries.append({
            "op": "rhals_iter", "tag": tag, "file": fname, "dtype": "f32",
            "m": m, "n": n, "k": k, "l": l,
            "inputs": [[l, n], [m, l], [m, k], [l, k], [n, k]],
            "outputs": [[m, k], [l, k], [n, k]],
        })

    for tag, m, n, k in HALS_VARIANTS:
        fn = jax.jit(model.hals_iteration)
        lowered = fn.lower(spec(m, n), spec(m, k), spec(n, k))
        fname = f"hals_iter_{m}x{n}_k{k}.hlo.txt"
        _write(out_dir, fname, to_hlo_text(lowered))
        entries.append({
            "op": "hals_iter", "tag": tag, "file": fname, "dtype": "f32",
            "m": m, "n": n, "k": k, "l": 0,
            "inputs": [[m, n], [m, k], [n, k]],
            "outputs": [[m, k], [n, k]],
        })

    for tag, m, n, l, q_iters in QB_VARIANTS:
        fn = jax.jit(functools.partial(model.qb_sketch, q_iters=q_iters))
        lowered = fn.lower(spec(m, n), spec(n, l))
        fname = f"qb_sketch_{m}x{n}_l{l}_q{q_iters}.hlo.txt"
        _write(out_dir, fname, to_hlo_text(lowered))
        entries.append({
            "op": "qb_sketch", "tag": tag, "file": fname, "dtype": "f32",
            "m": m, "n": n, "k": 0, "l": l, "q_iters": q_iters,
            "inputs": [[m, n], [n, l]],
            "outputs": [[m, l], [l, n]],
        })

    manifest = {"version": 1, "entries": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def _write(out_dir: str, fname: str, text: str) -> None:
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    manifest = build_all(args.out_dir)
    print(f"manifest: {len(manifest['entries'])} artifacts")


if __name__ == "__main__":
    main()

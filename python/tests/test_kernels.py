"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps shapes and seeds; every property mirrors an invariant the
Rust test suite checks on its side of the stack.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.hals_update import hals_sweep
from compile.kernels.matmul import matmul_tiled


def _case(seed, r, k, spd=True):
    rng = np.random.default_rng(seed)
    fac = rng.random((r, k), dtype=np.float32)
    num = rng.standard_normal((r, k)).astype(np.float32)
    other = rng.random((max(2 * k, 8), k), dtype=np.float32)
    gram = (other.T @ other).astype(np.float32) if spd else np.eye(k, dtype=np.float32)
    return jnp.asarray(fac), jnp.asarray(num), jnp.asarray(gram)


class TestHalsSweep:
    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31),
        r=st.integers(1, 300),
        k=st.integers(1, 24),
        block=st.sampled_from([8, 32, 256]),
    )
    def test_matches_ref_across_shapes(self, seed, r, k, block):
        fac, num, gram = _case(seed, r, k)
        got = hals_sweep(fac, num, gram, block_rows=block)
        want = ref.hals_sweep_ref(fac, num, gram)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 2**31),
        l1=st.floats(0.0, 2.0),
        l2=st.floats(0.0, 2.0),
        clamp=st.booleans(),
    )
    def test_regularized_and_unclamped_variants(self, seed, l1, l2, clamp):
        fac, num, gram = _case(seed, 64, 6)
        got = hals_sweep(fac, num, gram, l1=l1, l2=l2, clamp=clamp)
        want = ref.hals_sweep_ref(fac, num, gram, l1=l1, l2=l2, clamp=clamp)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)

    def test_clamped_output_nonnegative(self):
        fac, num, gram = _case(7, 128, 9)
        out = hals_sweep(fac, num - 10.0, gram)  # adversarial numerators
        assert float(out.min()) >= 0.0

    def test_dead_component_left_untouched(self):
        fac, num, gram = _case(11, 40, 5)
        gram = gram.at[2, :].set(0.0).at[:, 2].set(0.0)  # kill component 2
        out = hals_sweep(fac, num, gram)
        want = ref.hals_sweep_ref(fac, num, gram)
        np.testing.assert_allclose(out, want, rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(out[:, 2], fac[:, 2], rtol=0, atol=0)

    def test_fixed_point_at_ls_solution(self):
        # If fac solves the unconstrained LS (fac = num @ inv(gram)) and is
        # positive, a sweep is a no-op (same invariant as the Rust test).
        rng = np.random.default_rng(3)
        other = rng.random((40, 4), dtype=np.float32) + 0.1
        gram = jnp.asarray(other.T @ other)
        fac = jnp.asarray(rng.random((30, 4), dtype=np.float32) + 0.1)
        num = fac @ gram
        out = hals_sweep(fac, num, gram)
        np.testing.assert_allclose(out, fac, rtol=2e-4, atol=2e-4)

    def test_row_padding_harmless(self):
        # r not divisible by block_rows exercises the padding path.
        fac, num, gram = _case(13, 257, 7)
        got = hals_sweep(fac, num, gram, block_rows=64)
        want = ref.hals_sweep_ref(fac, num, gram)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


class TestMatmulTiled:
    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31),
        m=st.integers(1, 200),
        k=st.integers(1, 120),
        n=st.integers(1, 200),
    )
    def test_matches_ref_across_shapes(self, seed, m, k, n):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
        b = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
        got = matmul_tiled(a, b, bm=64, bn=64, bk=64)
        want = ref.matmul_ref(a, b)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("tiles", [(16, 16, 16), (32, 64, 16), (256, 256, 256)])
    def test_tile_shape_invariance(self, tiles):
        bm, bn, bk = tiles
        rng = np.random.default_rng(5)
        a = jnp.asarray(rng.random((100, 70), dtype=np.float32))
        b = jnp.asarray(rng.random((70, 90), dtype=np.float32))
        got = matmul_tiled(a, b, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(got, a @ b, rtol=2e-4, atol=2e-4)

    def test_identity(self):
        a = jnp.eye(33, dtype=jnp.float32)
        b = jnp.asarray(np.random.default_rng(6).random((33, 21), dtype=np.float32))
        np.testing.assert_allclose(matmul_tiled(a, b, bm=16, bn=16, bk=16), b,
                                   rtol=1e-6, atol=1e-6)

"""L2 model-graph correctness: composed iterations vs oracle, plus the
algorithmic invariants (nonnegativity, descent, orthonormal sketch)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _low_rank(rng, m, n, r, noise=1e-3):
    # Small noise keeps sketches full-rank: CholeskyQR (like any Gram-based
    # orthonormalization) returns near-zero columns for directions beyond
    # the numerical rank, which is fine for QB but would make naive
    # "Q^T Q == I" assertions vacuous.
    u = rng.random((m, r), dtype=np.float32)
    v = rng.random((r, n), dtype=np.float32)
    return jnp.asarray(u @ v + noise * rng.random((m, n), dtype=np.float32))


def _rhals_state(seed, m=80, n=60, k=4, l=12):
    rng = np.random.default_rng(seed)
    x = _low_rank(rng, m, n, k)
    omega = jnp.asarray(rng.random((n, l), dtype=np.float32))
    q, b = ref.qb_sketch_ref(x, omega, 2)
    w = jnp.asarray(rng.random((m, k), dtype=np.float32))
    wt = q.T @ w
    ht = jnp.asarray(rng.random((n, k), dtype=np.float32))
    return x, q, b, w, wt, ht


class TestRhalsIteration:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31))
    def test_matches_ref(self, seed):
        _, q, b, w, wt, ht = _rhals_state(seed)
        got = model.rhals_iteration(b, q, w, wt, ht)
        want = ref.rhals_iteration_ref(b, q, w, wt, ht)
        for g, ww in zip(got, want):
            np.testing.assert_allclose(g, ww, rtol=2e-4, atol=2e-4)

    def test_nonnegativity_and_descent(self):
        x, q, b, w, wt, ht = _rhals_state(1)

        def comp_err(wt, ht):
            return float(jnp.linalg.norm(b - wt @ ht.T))

        prev = comp_err(wt, ht)
        for _ in range(30):
            w, wt, ht = model.rhals_iteration(b, q, w, wt, ht)
        assert float(w.min()) >= 0.0
        assert float(ht.min()) >= 0.0
        cur = comp_err(wt, ht)
        assert cur < prev, f"compressed residual should fall: {prev} -> {cur}"
        # And the *true* reconstruction is decent for exact low-rank data.
        rel = float(jnp.linalg.norm(x - w @ ht.T) / jnp.linalg.norm(x))
        assert rel < 0.15, rel

    def test_l1_regularization_sparsifies(self):
        _, q, b, w, wt, ht = _rhals_state(2)
        w1, wt1, ht1 = w, wt, ht
        for _ in range(25):
            w, wt, ht = model.rhals_iteration(b, q, w, wt, ht)
            w1, wt1, ht1 = model.rhals_iteration(b, q, w1, wt1, ht1, l1_w=0.5)
        frac = lambda a: float((a == 0).mean())
        assert frac(w1) > frac(w), (frac(w1), frac(w))


class TestHalsIteration:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31))
    def test_matches_ref(self, seed):
        rng = np.random.default_rng(seed)
        x = _low_rank(rng, 50, 40, 3)
        w = jnp.asarray(rng.random((50, 3), dtype=np.float32))
        ht = jnp.asarray(rng.random((40, 3), dtype=np.float32))
        got = model.hals_iteration(x, w, ht)
        want = ref.hals_iteration_ref(x, w, ht)
        for g, ww in zip(got, want):
            np.testing.assert_allclose(g, ww, rtol=2e-4, atol=2e-4)

    def test_descends_objective(self):
        rng = np.random.default_rng(3)
        x = _low_rank(rng, 60, 50, 4)
        w = jnp.asarray(rng.random((60, 4), dtype=np.float32))
        ht = jnp.asarray(rng.random((50, 4), dtype=np.float32))
        errs = []
        for _ in range(20):
            w, ht = model.hals_iteration(x, w, ht)
            errs.append(float(jnp.linalg.norm(x - w @ ht.T)))
        assert all(b <= a + 1e-4 for a, b in zip(errs, errs[1:])), errs
        assert errs[-1] < errs[0]


class TestQbSketch:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31), m=st.integers(20, 120), n=st.integers(20, 120))
    def test_q_orthonormal_and_reconstructs_low_rank(self, seed, m, n):
        rng = np.random.default_rng(seed)
        r, l = 4, 12
        x = _low_rank(rng, m, n, r)
        omega = jnp.asarray(rng.random((n, min(l, min(m, n))), dtype=np.float32))
        q, b = model.qb_sketch(x, omega, q_iters=2)
        # The f32 CholeskyQR path is rank-revealing: directions at or below
        # its numerical floor come out as shrunken/zero columns. Assert
        # orthonormality on the live block and reconstruction overall.
        qtq = np.asarray(q.T @ q)
        live = np.diag(qtq) > 0.5
        assert live.sum() >= 4, f"true rank must survive: {np.diag(qtq)}"
        sub = qtq[np.ix_(live, live)]
        np.testing.assert_allclose(sub, np.eye(live.sum()), atol=5e-3)
        # Dead/boundary columns must not correlate with live ones.
        off = qtq[np.ix_(live, ~live)]
        if off.size:
            assert np.abs(off).max() < 5e-2, np.abs(off).max()
        rel = float(jnp.linalg.norm(x - q @ b) / jnp.linalg.norm(x))
        assert rel < 2e-2, rel

    def test_matches_ref_pipeline(self):
        rng = np.random.default_rng(4)
        x = _low_rank(rng, 70, 50, 5)
        omega = jnp.asarray(rng.random((50, 15), dtype=np.float32))
        q, b = model.qb_sketch(x, omega, q_iters=1)
        qr_, br_ = ref.qb_sketch_ref(x, omega, 1)
        # Compare the subspace products (individual columns of Q are
        # fp-order sensitive in the oversampled noise directions).
        np.testing.assert_allclose(q @ b, qr_ @ br_, rtol=5e-3, atol=5e-3)

"""AOT pipeline: lowering produces loadable HLO text and a sane manifest."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_to_hlo_text_smoke():
    fn = jax.jit(lambda a, b: (a @ b,))
    lowered = fn.lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_rhals_artifact_contains_expected_shapes(tmp_path):
    fn = jax.jit(model.rhals_iteration)
    m, n, k, l = 30, 20, 3, 8
    lowered = fn.lower(
        aot.spec(l, n), aot.spec(m, l), aot.spec(m, k), aot.spec(l, k), aot.spec(n, k)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert f"f32[{m},{k}]" in text  # W in the signature
    assert f"f32[{l},{n}]" in text  # B in the signature


def test_manifest_roundtrip(tmp_path, monkeypatch):
    # Shrink the variant lists so the test is fast.
    monkeypatch.setattr(aot, "RHALS_VARIANTS", [("t", 30, 20, 3, 8, 2)])
    monkeypatch.setattr(aot, "HALS_VARIANTS", [("t", 30, 20, 3)])
    monkeypatch.setattr(aot, "QB_VARIANTS", [("t", 30, 20, 8, 1)])
    manifest = aot.build_all(str(tmp_path))
    assert len(manifest["entries"]) == 3
    on_disk = json.loads((tmp_path / "manifest.json").read_text())
    assert on_disk == manifest
    for e in on_disk["entries"]:
        path = tmp_path / e["file"]
        assert path.exists(), e
        assert "HloModule" in path.read_text()[:200]
        assert e["dtype"] == "f32"
        assert all(len(s) == 2 for s in e["inputs"] + e["outputs"])


def test_lowered_hlo_has_no_lapack_custom_calls(tmp_path):
    """The 0.5.1 PJRT runtime cannot resolve jax's LAPACK custom-calls; the
    qb_sketch graph must only use native HLO (CholeskyQR2 design)."""
    import functools

    fn = jax.jit(functools.partial(model.qb_sketch, q_iters=2))
    lowered = fn.lower(aot.spec(40, 30), aot.spec(30, 10))
    text = aot.to_hlo_text(lowered)
    assert "lapack" not in text.lower()


def test_artifact_numerics_match_eager(tmp_path):
    """The lowered graph computes what eager jax computes."""
    rng = np.random.default_rng(0)
    m, n, k, l = 30, 20, 3, 8
    b = jnp.asarray(rng.random((l, n), dtype=np.float32))
    q = jnp.asarray(np.linalg.qr(rng.standard_normal((m, l)))[0].astype(np.float32))
    w = jnp.asarray(rng.random((m, k), dtype=np.float32))
    wt = q.T @ w
    ht = jnp.asarray(rng.random((n, k), dtype=np.float32))
    eager = model.rhals_iteration(b, q, w, wt, ht)
    compiled = jax.jit(model.rhals_iteration)(b, q, w, wt, ht)
    for e, c in zip(eager, compiled):
        np.testing.assert_allclose(e, c, rtol=1e-5, atol=1e-5)

//! Seeded-defect regression tests.
//!
//! The fixtures prove the rules fire on synthetic code; these prove
//! they fire on the *real* tree. Each test copies a production file
//! into a scratch directory, removes exactly one invariant-carrying
//! line (a pool release, a deterministic-reduce annotation), and
//! asserts the lint run flips to failure with the offending site named
//! in the message. If a rule rots to the point where it no longer
//! catches the very defect it was built for, this is what fails.

use std::fs;
use std::path::PathBuf;

fn scratch(sub: &str) -> PathBuf {
    let tag = format!("randnmf-lint-seeded-{}-{sub}", std::process::id());
    let dir = std::env::temp_dir().join(tag);
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).expect("scratch dir");
    dir
}

fn real_source(rel: &str) -> String {
    let path = format!("{}/../../{rel}", env!("CARGO_MANIFEST_DIR"));
    fs::read_to_string(&path).unwrap_or_else(|e| panic!("{path}: {e}"))
}

#[test]
fn removing_one_release_from_srht_flips_the_lint_to_failure() {
    let src = real_source("rust/src/sketch/srht.rs");
    let needle = "ws.release_vec(stage);";
    assert!(src.contains(needle), "seed target moved; update this test");
    let mut dropped = false;
    let mutated: String = src
        .lines()
        .filter(|l| {
            if !dropped && l.trim() == needle {
                dropped = true;
                return false;
            }
            true
        })
        .map(|l| format!("{l}\n"))
        .collect();
    assert!(dropped, "no line matched the seed target exactly");

    let dir = scratch("srht");
    fs::write(dir.join("srht.rs"), mutated).expect("write mutated copy");
    let report = randnmf_lint::run(&[dir.display().to_string()]).expect("scratch readable");
    let _ = fs::remove_dir_all(&dir);

    assert!(!report.findings.is_empty(), "seeded leak went undetected");
    assert!(
        report
            .findings
            .iter()
            .any(|f| f.code == "L1" && f.message.contains("fn srht_sketch_apply")),
        "expected an L1 finding naming srht_sketch_apply, got:\n{}",
        report.findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
}

#[test]
fn removing_one_reduce_annotation_from_gemm_flips_the_lint_to_failure() {
    let src = real_source("rust/src/linalg/gemm.rs");
    // The annotation is a two-line comment block; drop both lines so
    // the call site below is genuinely unannotated.
    let marker = "deterministic-reduce(disjoint row chunks";
    assert!(src.contains(marker), "seed target moved; update this test");
    let lines: Vec<&str> = src.lines().collect();
    let at = lines.iter().position(|l| l.contains(marker)).unwrap();
    let mutated: String = lines
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != at && *i != at + 1)
        .map(|(_, l)| format!("{l}\n"))
        .collect();

    let dir = scratch("gemm");
    fs::write(dir.join("gemm.rs"), mutated).expect("write mutated copy");
    let report = randnmf_lint::run(&[dir.display().to_string()]).expect("scratch readable");
    let _ = fs::remove_dir_all(&dir);

    assert!(
        report
            .findings
            .iter()
            .any(|f| f.code == "L7" && f.message.contains("`run_row_split` call site lacks")),
        "expected an L7 finding at the stripped call site, got:\n{}",
        report.findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
}

//! Exact-count / exact-position assertions over the fixtures corpus.
//!
//! Each violating fixture must produce precisely its intended findings
//! (right rule, right line); each conforming fixture must lint clean.
//! This is what keeps the lints honest: a rule that silently stops
//! firing fails these tests before it lets a regression into the tree.

use randnmf_lint::{run, Finding};

fn lint_fixture(name: &str) -> Vec<Finding> {
    let path = format!("{}/fixtures/{name}", env!("CARGO_MANIFEST_DIR"));
    run(&[path]).expect("fixture readable").findings
}

fn assert_clean(name: &str) {
    let f = lint_fixture(name);
    assert!(f.is_empty(), "{name} should lint clean, got:\n{}", render(&f));
}

fn render(f: &[Finding]) -> String {
    f.iter().map(|w| w.to_string()).collect::<Vec<_>>().join("\n")
}

#[test]
fn l1_leak_is_flagged_at_the_fn() {
    let f = lint_fixture("l1_leak.rs");
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!(f[0].code, "L1");
    assert_eq!(f[0].line, 11);
    assert!(f[0].message.contains("fn leaky: 2 acquire(s) vs 1 release(s)"));
}

#[test]
fn l1_balanced_recycled_and_waived_are_clean() {
    assert_clean("l1_clean.rs");
}

#[test]
fn l2_every_banned_token_is_flagged_once() {
    let f = lint_fixture("l2_banned.rs");
    let expected: [(usize, &str); 7] = [
        (5, "Vec::new"),
        (6, "vec!"),
        (7, ".to_vec()"),
        (8, ".clone()"),
        (9, "format!"),
        (10, "Box::new"),
        (11, "String::from"),
    ];
    assert_eq!(f.len(), expected.len(), "{}", render(&f));
    for (line, tok) in expected {
        assert!(
            f.iter().any(|w| w.code == "L2"
                && w.line == line
                && w.message == format!("fn hot: `{tok}` in zero-alloc fn")),
            "missing `{tok}` at line {line} in:\n{}",
            render(&f)
        );
    }
}

#[test]
fn l2_waivers_and_unannotated_fns_are_clean() {
    assert_clean("l2_clean.rs");
}

#[test]
fn l3_bare_unsafe_is_flagged() {
    let f = lint_fixture("l3_bare.rs");
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!(f[0].code, "L3");
    assert_eq!(f[0].line, 7);
}

#[test]
fn l3_all_audit_placements_are_accepted() {
    assert_clean("l3_safety.rs");
}

#[test]
fn l4_missing_variant_is_flagged_at_the_surface() {
    let f = lint_fixture("l4_missing.rs");
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!(f[0].code, "L4");
    assert_eq!(f[0].line, 10);
    assert!(f[0].message.contains("fn pick: missing Strategy::Streaming"));
}

#[test]
fn l4_complete_surface_is_clean() {
    assert_clean("l4_complete.rs");
}

#[test]
fn l4_core_enum_without_surface_trips_the_wire() {
    let f = lint_fixture("l4_unregistered.rs");
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!(f[0].code, "L4");
    assert_eq!(f[0].line, 3);
    assert!(f[0].message.contains("enum SketchKind: no registered dispatch surface"));
}

#[test]
fn failpoints_symbol_without_gate_is_flagged() {
    let f = lint_fixture("fp_ungated.rs");
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!(f[0].code, "L4");
    assert_eq!(f[0].line, 4);
    assert!(f[0].message.contains("not cfg-gated"));
}

#[test]
fn failpoints_gated_within_three_lines_is_clean() {
    assert_clean("fp_gated.rs");
}

#[test]
fn l5_long_line_reports_its_width() {
    let f = lint_fixture("l5_long.rs");
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!(f[0].code, "L5");
    assert_eq!(f[0].line, 4);
    assert!(f[0].message.contains("exceeds 100 columns (108)"));
}

#[test]
fn l5_unbalanced_bracket_is_flagged_once() {
    let f = lint_fixture("l5_unbalanced.rs");
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!(f[0].code, "L5");
    assert_eq!(f[0].line, 4);
    assert!(f[0].message.contains("unbalanced bracket ']'"));
}

#[test]
fn l5_brackets_in_strings_and_comments_are_clean() {
    assert_clean("l5_clean.rs");
}

#[test]
fn whole_corpus_totals_are_stable() {
    let dir = format!("{}/fixtures", env!("CARGO_MANIFEST_DIR"));
    let report = run(&[dir]).expect("fixtures readable");
    assert_eq!(report.files_scanned, 14);
    // 1 L1 + 7 L2 + 1 L3 + 3 L4 (missing variant, unregistered core
    // enum, ungated failpoints) + 2 L5.
    assert_eq!(report.findings.len(), 14, "{}", render(&report.findings));
    let count = |c: &str| report.findings.iter().filter(|w| w.code == c).count();
    assert_eq!(count("L1"), 1);
    assert_eq!(count("L2"), 7);
    assert_eq!(count("L3"), 1);
    assert_eq!(count("L4"), 3);
    assert_eq!(count("L5"), 2);
}

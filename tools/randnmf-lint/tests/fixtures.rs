//! Exact-count / exact-position assertions over the fixtures corpus.
//!
//! Each violating fixture must produce precisely its intended findings
//! (right rule, right line); each conforming fixture must lint clean.
//! This is what keeps the lints honest: a rule that silently stops
//! firing fails these tests before it lets a regression into the tree.

use randnmf_lint::{run, Finding};

fn lint_fixture(name: &str) -> Vec<Finding> {
    let path = format!("{}/fixtures/{name}", env!("CARGO_MANIFEST_DIR"));
    run(&[path]).expect("fixture readable").findings
}

fn assert_clean(name: &str) {
    let f = lint_fixture(name);
    assert!(f.is_empty(), "{name} should lint clean, got:\n{}", render(&f));
}

fn render(f: &[Finding]) -> String {
    f.iter().map(|w| w.to_string()).collect::<Vec<_>>().join("\n")
}

#[test]
fn l1_leak_is_flagged_at_the_fn() {
    let f = lint_fixture("l1_leak.rs");
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!(f[0].code, "L1");
    assert_eq!(f[0].line, 11);
    assert!(f[0].message.contains("fn leaky: 2 acquire(s) vs 1 release(s)"));
}

#[test]
fn l1_balanced_recycled_and_waived_are_clean() {
    assert_clean("l1_clean.rs");
}

#[test]
fn l2_every_banned_token_is_flagged_once() {
    let f = lint_fixture("l2_banned.rs");
    let expected: [(usize, &str); 7] = [
        (5, "Vec::new"),
        (6, "vec!"),
        (7, ".to_vec()"),
        (8, ".clone()"),
        (9, "format!"),
        (10, "Box::new"),
        (11, "String::from"),
    ];
    assert_eq!(f.len(), expected.len(), "{}", render(&f));
    for (line, tok) in expected {
        assert!(
            f.iter().any(|w| w.code == "L2"
                && w.line == line
                && w.message == format!("fn hot: `{tok}` in zero-alloc fn")),
            "missing `{tok}` at line {line} in:\n{}",
            render(&f)
        );
    }
}

#[test]
fn l2_waivers_and_unannotated_fns_are_clean() {
    assert_clean("l2_clean.rs");
}

#[test]
fn l3_bare_unsafe_is_flagged() {
    let f = lint_fixture("l3_bare.rs");
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!(f[0].code, "L3");
    assert_eq!(f[0].line, 7);
}

#[test]
fn l3_all_audit_placements_are_accepted() {
    assert_clean("l3_safety.rs");
}

#[test]
fn l4_missing_variant_is_flagged_at_the_surface() {
    let f = lint_fixture("l4_missing.rs");
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!(f[0].code, "L4");
    assert_eq!(f[0].line, 10);
    assert!(f[0].message.contains("fn pick: missing Strategy::Streaming"));
}

#[test]
fn l4_complete_surface_is_clean() {
    assert_clean("l4_complete.rs");
}

#[test]
fn l4_core_enum_without_surface_trips_the_wire() {
    let f = lint_fixture("l4_unregistered.rs");
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!(f[0].code, "L4");
    assert_eq!(f[0].line, 3);
    assert!(f[0].message.contains("enum SketchKind: no registered dispatch surface"));
}

#[test]
fn failpoints_symbol_without_gate_is_flagged() {
    let f = lint_fixture("fp_ungated.rs");
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!(f[0].code, "L4");
    assert_eq!(f[0].line, 4);
    assert!(f[0].message.contains("not cfg-gated"));
}

#[test]
fn failpoints_gated_within_three_lines_is_clean() {
    assert_clean("fp_gated.rs");
}

#[test]
fn l5_long_line_reports_its_width() {
    let f = lint_fixture("l5_long.rs");
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!(f[0].code, "L5");
    assert_eq!(f[0].line, 4);
    assert!(f[0].message.contains("exceeds 100 columns (108)"));
}

#[test]
fn l5_unbalanced_bracket_is_flagged_once() {
    let f = lint_fixture("l5_unbalanced.rs");
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!(f[0].code, "L5");
    assert_eq!(f[0].line, 4);
    assert!(f[0].message.contains("unbalanced bracket ']'"));
}

#[test]
fn l5_brackets_in_strings_and_comments_are_clean() {
    assert_clean("l5_clean.rs");
}

#[test]
fn l6_double_release_is_flagged_at_the_second_release() {
    let f = lint_fixture("l6_double.rs");
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!(f[0].code, "L6");
    assert_eq!(f[0].line, 6);
    assert!(f[0].message.contains("fn double_release: `a` released twice"));
}

#[test]
fn l6_release_before_acquire_is_flagged() {
    let f = lint_fixture("l6_order.rs");
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!(f[0].code, "L6");
    assert_eq!(f[0].line, 4);
    assert!(f[0].message.contains("`v` released before it is acquired"));
}

#[test]
fn l6_kind_mismatch_is_flagged_at_the_release() {
    let f = lint_fixture("l6_kind.rs");
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!(f[0].code, "L6");
    assert_eq!(f[0].line, 6);
    assert!(f[0].message.contains("`m` acquired as mat but released as vec"));
}

#[test]
fn l6_early_exits_with_outstanding_buffers_are_flagged() {
    let f = lint_fixture("l6_leak.rs");
    assert_eq!(f.len(), 2, "{}", render(&f));
    assert!(f.iter().all(|w| w.code == "L6"));
    assert!(
        f.iter().any(|w| w.line == 5 && w.message.contains("early `?` leaks acquired a")),
        "{}",
        render(&f)
    );
    assert!(
        f.iter().any(|w| w.line == 13 && w.message.contains("early return leaks acquired b")),
        "{}",
        render(&f)
    );
}

#[test]
fn l6_waivers_recycle_and_caller_owned_releases_are_clean() {
    assert_clean("l6_clean.rs");
}

#[test]
fn l7_unordered_collections_in_scoped_path_are_flagged_per_line() {
    let f = lint_fixture("runtime/l7_unordered.rs");
    assert_eq!(f.len(), 3, "{}", render(&f));
    for line in [4, 6, 7] {
        assert!(
            f.iter().any(|w| w.code == "L7"
                && w.line == line
                && w.message.contains("`HashMap` in a determinism-scoped path")),
            "missing HashMap finding at line {line} in:\n{}",
            render(&f)
        );
    }
}

#[test]
fn l7_waived_unordered_collections_are_clean() {
    assert_clean("runtime/l7_clean.rs");
}

#[test]
fn l7_unannotated_reduce_call_sites_are_flagged() {
    let f = lint_fixture("l7_reduce.rs");
    assert_eq!(f.len(), 2, "{}", render(&f));
    assert!(
        f.iter().any(|w| w.code == "L7"
            && w.line == 5
            && w.message.contains("`run_row_split` call site lacks")),
        "{}",
        render(&f)
    );
    assert!(
        f.iter().any(|w| w.code == "L7"
            && w.line == 6
            && w.message.contains("`inner_split_reduce` call site lacks")),
        "{}",
        render(&f)
    );
}

#[test]
fn l7_annotated_reduce_sites_and_declarations_are_clean() {
    assert_clean("l7_reduce_clean.rs");
}

#[test]
fn callgraph_closure_reports_the_full_path_to_the_allocation() {
    let f = lint_fixture("cg_closure.rs");
    assert_eq!(f.len(), 1, "{}", render(&f));
    assert_eq!(f[0].code, "L2");
    assert_eq!(f[0].line, 6);
    assert!(
        f[0].message.contains("zero-alloc call path root -> middle -> leaf"),
        "{}",
        render(&f)
    );
    assert!(f[0].message.contains("cg_closure.rs:14"), "{}", render(&f));
}

#[test]
fn callgraph_stops_at_annotated_waived_ambiguous_and_std_names() {
    assert_clean("cg_clean.rs");
}

#[test]
fn whole_corpus_totals_are_stable() {
    let dir = format!("{}/fixtures", env!("CARGO_MANIFEST_DIR"));
    let report = run(&[dir]).expect("fixtures readable");
    assert_eq!(report.files_scanned, 25);
    // 1 L1 + 8 L2 (7 banned tokens + 1 closure path) + 1 L3 + 3 L4
    // (missing variant, unregistered core enum, ungated failpoints)
    // + 2 L5 + 5 L6 + 5 L7.
    assert_eq!(report.findings.len(), 25, "{}", render(&report.findings));
    let count = |c: &str| report.findings.iter().filter(|w| w.code == c).count();
    assert_eq!(count("L1"), 1);
    assert_eq!(count("L2"), 8);
    assert_eq!(count("L3"), 1);
    assert_eq!(count("L4"), 3);
    assert_eq!(count("L5"), 2);
    assert_eq!(count("L6"), 5);
    assert_eq!(count("L7"), 5);
}

#[test]
fn fixture_ledger_matches_byte_for_byte() {
    // The golden ledger pins every finding — rule, position, and full
    // message text — across the whole corpus. CI re-derives it from a
    // `cargo run` over `fixtures/` and diffs; this test does the same
    // in-process so a drifting message fails before it reaches CI.
    let manifest = env!("CARGO_MANIFEST_DIR");
    let report = run(&[format!("{manifest}/fixtures")]).expect("fixtures readable");
    let rendered: String = report
        .findings
        .iter()
        .map(|f| f.to_string().replace(&format!("{manifest}/"), "") + "\n")
        .collect();
    let golden = include_str!("../fixtures/LEDGER.txt");
    assert_eq!(rendered, golden, "fixtures/LEDGER.txt is stale; regenerate it");
}

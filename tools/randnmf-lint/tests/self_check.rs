//! The real tree must lint clean.
//!
//! The fixtures prove the lints can fire; this proves `rust/src`
//! satisfies every invariant. Run from anywhere — the path is anchored
//! to this crate's manifest.

#[test]
fn real_tree_is_clean() {
    let root = format!("{}/../../rust/src", env!("CARGO_MANIFEST_DIR"));
    let report = randnmf_lint::run(&[root]).expect("rust/src readable");
    let msgs: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
    assert!(msgs.is_empty(), "lint findings in rust/src:\n{}", msgs.join("\n"));
    // Guard against the walker silently scanning an empty directory and
    // declaring victory.
    assert!(
        report.files_scanned >= 60,
        "expected the full tree, scanned only {} files",
        report.files_scanned
    );
}

//! The real tree must lint clean.
//!
//! The fixtures prove the lints can fire; this proves the whole
//! workspace — library sources, integration tests, benches, and the
//! tools themselves — satisfies every invariant. Run from anywhere —
//! paths are anchored to this crate's manifest. The walker skips
//! directories named `fixtures`, so the deliberately-violating corpus
//! does not pollute the sweep.

#[test]
fn real_tree_is_clean() {
    let up = format!("{}/../..", env!("CARGO_MANIFEST_DIR"));
    let roots = [
        format!("{up}/rust/src"),
        format!("{up}/rust/tests"),
        format!("{up}/rust/benches"),
        format!("{up}/tools"),
    ];
    let report = randnmf_lint::run(&roots).expect("workspace readable");
    let msgs: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
    assert!(msgs.is_empty(), "lint findings in the real tree:\n{}", msgs.join("\n"));
    // Guard against the walker silently scanning an empty directory and
    // declaring victory. rust/src alone is >60 files; the widened sweep
    // adds tests, benches, and the lint tool itself.
    assert!(
        report.files_scanned >= 90,
        "expected the full tree, scanned only {} files",
        report.files_scanned
    );
}

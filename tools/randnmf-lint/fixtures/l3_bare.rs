//! L3 violating fixture: unsafe with no SAFETY comment anywhere near.

pub fn read_raw(p: *const f64) -> f64 {
    let x = 1.0;
    let y = 2.0;
    let z = 3.0;
    unsafe { *p + x + y + z }
}

//! L2 violating fixture: every banned allocation token in a zero-alloc fn.

// lint: zero-alloc
pub fn hot(xs: &[f64]) -> usize {
    let a: Vec<f64> = Vec::new();
    let b = vec![0.0; 4];
    let c = xs.to_vec();
    let d = c.clone();
    let e = format!("{}", xs.len());
    let f = Box::new(1.0);
    let g = String::from("x");
    a.len() + b.len() + d.len() + e.len() + g.len() + (*f as usize)
}

//! Failpoints tripwire violating fixture: symbol without a cfg gate.

pub fn trigger() {
    crate::testing::failpoints::hit("qb_after_sketch");
}

//! L4 conforming fixture: the surface names every variant.

pub enum Strategy {
    Direct,
    Blocked,
    Streaming,
}

// lint: dispatch(Strategy)
pub fn pick(s: &Strategy) -> u8 {
    match s {
        Strategy::Direct => 0,
        Strategy::Blocked => 1,
        Strategy::Streaming => 2,
    }
}

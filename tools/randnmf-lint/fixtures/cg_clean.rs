//! Callgraph conforming fixture: annotated, waived, ambiguous, and
//! std-shadowed callees all stop the walk.

// lint: zero-alloc
fn root(xs: &[f64], s: &mut State) -> f64 {
    audited(xs) + waived(xs) + ambiguous(xs) + s.items.take().unwrap_or(0.0)
}

// lint: zero-alloc
fn audited(xs: &[f64]) -> f64 {
    xs[0]
}

// lint: allow(zero-alloc-closure): cold path, allocates by design
fn waived(xs: &[f64]) -> f64 {
    xs.to_vec()[0]
}

fn ambiguous(xs: &[f64]) -> f64 {
    xs[0] + 1.0
}

fn ambiguous(xs: &[f64]) -> f64 {
    xs.to_vec()[0]
}

fn take(r: &mut Reader) -> Buf {
    r.data.to_vec()
}

//! L2 conforming fixture: waivers honored, unannotated fns unchecked.

// lint: zero-alloc
pub fn hot(xs: &[f64], ws: &[f64]) -> f64 {
    let mut acc = 0.0;
    for (x, w) in xs.iter().zip(ws.iter()) {
        acc += x * w;
    }
    let t: Vec<f64> = Vec::new(); // lint: allow(zero-alloc): empty, no alloc
    // lint: allow(zero-alloc): empty Vec::new does not allocate; the
    // trace only grows on the cold path.
    let u: Vec<f64> = Vec::new();
    acc + (t.len() + u.len()) as f64
}

pub fn cold(xs: &[f64]) -> Vec<f64> {
    xs.to_vec()
}

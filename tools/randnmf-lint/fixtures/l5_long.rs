//! L5 violating fixture: one line over 100 columns.

pub fn long_line() -> u32 {
    let x = 1; // xxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxx
    x
}

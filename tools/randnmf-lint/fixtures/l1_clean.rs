//! L1 conforming fixture: balanced, recycled, or explicitly waived.

pub fn balanced(pool: &mut Pool) {
    let a = pool.acquire_vec(8);
    pool.release_vec(a);
}

pub fn bulk(pool: &mut Pool) {
    let a = pool.acquire_mat(4, 4);
    let b = pool.acquire_mat(4, 4);
    pool.recycle(&mut [a, b]);
}

// lint: transfers-buffers: the factor matrices move out to the caller.
pub fn mover(pool: &mut Pool) -> usize {
    pool.acquire_mat(4, 4)
}

// lint: allow(acquire-release): ledger audited by the drop guard.
pub fn guarded(pool: &mut Pool) -> usize {
    pool.acquire_vec(3)
}

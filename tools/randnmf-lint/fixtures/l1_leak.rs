//! L1 violating fixture: acquires outnumber releases, no recycle.

pub struct Pool;
impl Pool {
    pub fn acquire_mat(&mut self, _r: usize, _c: usize) -> usize {
        0
    }
    pub fn release_mat(&mut self, _m: usize) {}
}

pub fn leaky(pool: &mut Pool) -> usize {
    let a = pool.acquire_mat(4, 4);
    let b = pool.acquire_mat(2, 2);
    pool.release_mat(a);
    b
}

//! L6 violating fixture: early exits leak outstanding pool buffers.

fn leak_on_try(pool: &mut Pool) -> Result<(), E> {
    let a = pool.acquire_mat(4, 4);
    fallible()?;
    pool.release_mat(a);
    Ok(())
}

fn leak_on_return(pool: &mut Pool, bail: bool) {
    let b = pool.acquire_vec(8);
    if bail {
        return;
    }
    pool.release_vec(b);
}

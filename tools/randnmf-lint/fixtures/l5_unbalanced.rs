//! L5 violating fixture: an unbalanced bracket on the masked view.

pub fn broken() {
    let pair = (1, 2];
}

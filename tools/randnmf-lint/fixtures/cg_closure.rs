//! Callgraph violating fixture: a zero-alloc fn reaches an allocating
//! callee two hops away.

// lint: zero-alloc
fn root(xs: &[f64]) -> f64 {
    middle(xs)
}

fn middle(xs: &[f64]) -> f64 {
    leaf(xs)
}

fn leaf(xs: &[f64]) -> f64 {
    let v = xs.to_vec();
    v[0]
}

//! L7 conforming fixture: unordered collections carry determinism
//! waivers naming why order is never observed.

// lint: allow(determinism): membership set, iteration order never observed
use std::collections::HashSet;

fn seen(xs: &[u32]) -> usize {
    // lint: allow(determinism): only len() is read, which is order-free
    let s: HashSet<u32> = xs.iter().copied().collect();
    s.len()
}

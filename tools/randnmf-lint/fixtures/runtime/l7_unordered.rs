//! L7 violating fixture: unordered collections in a determinism-scoped
//! path (this fixture lives under a `runtime/` segment on purpose).

use std::collections::HashMap;

fn tally(xs: &[u32]) -> HashMap<u32, u32> {
    let mut m = HashMap::new();
    for x in xs {
        *m.entry(*x).or_insert(0) += 1;
    }
    m
}

//! L3 conforming fixture: every unsafe is covered by a SAFETY audit —
//! same-line, block-above, through attributes, grouped unsafe impls,
//! and a split statement within the two-code-line tolerance.

pub fn same_line(p: *const f64) -> f64 {
    unsafe { *p } // SAFETY: caller guarantees p is valid.
}

pub fn above(p: *const f64) -> f64 {
    // SAFETY: caller guarantees p is valid for reads.
    unsafe { *p }
}

pub fn through_attrs(p: *const f64) -> f64 {
    // SAFETY: caller guarantees p is valid for reads.
    #[allow(clippy::let_and_return)]
    let v = unsafe { *p };
    v
}

pub struct SendA(*mut f64);
pub struct SendB(*mut f64);

// SAFETY: both wrappers hand out the pointer only behind &mut self.
unsafe impl Send for SendA {}
unsafe impl Send for SendB {}

pub fn mid_statement(p: *const f64) -> f64 {
    // SAFETY: caller guarantees p is valid; the statement below is
    // split across lines.
    let value =
        unsafe { *p };
    value
}

//! L6 conforming fixture: balanced pairs, caller-owned releases,
//! recycle, fn-level waivers, and line-level leak waivers all pass.

fn balanced(pool: &mut Pool) {
    let a = pool.acquire_mat(4, 4);
    pool.release_mat(a);
}

fn caller_owned(pool: &mut Pool, m: Mat) {
    pool.release_mat(m);
}

fn recycled(pool: &mut Pool) {
    let a = pool.acquire_mat(4, 4);
    let b = pool.acquire_vec(8);
    pool.recycle(&mut [a, b]);
}

// lint: transfers-buffers: the result moves out to the caller
fn mover(pool: &mut Pool) -> Result<Mat, E> {
    let out = pool.acquire_mat(4, 4);
    fallible()?;
    Ok(out)
}

fn waived_line(pool: &mut Pool) -> Result<(), E> {
    let a = pool.acquire_vec(8);
    fallible()?; // lint: allow(leak-on-error): pool is rebuilt on error
    pool.release_vec(a);
    Ok(())
}

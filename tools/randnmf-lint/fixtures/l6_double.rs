//! L6 violating fixture: the same binding is released twice.

fn double_release(pool: &mut Pool) {
    let a = pool.acquire_mat(4, 4);
    pool.release_mat(a);
    pool.release_mat(a);
}

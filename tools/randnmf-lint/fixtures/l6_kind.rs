//! L6 violating fixture: acquired as one buffer kind, released as the
//! other.

fn kind_mismatch(pool: &mut Pool) {
    let m = pool.acquire_mat(4, 4);
    pool.release_vec(m);
}

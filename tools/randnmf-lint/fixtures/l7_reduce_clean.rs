//! L7 conforming fixture: every split/reduce call site names why its
//! reduce order is fixed, and a declaration is not a call site.

fn drive(pool: &mut Pool, out: &mut [f64]) {
    // lint: deterministic-reduce(disjoint row chunks, no accumulation)
    pool.run_row_split(4, 8, 8, out, &noop);
    pool.inner_split_reduce(4, 100, out, &acc); // lint: deterministic-reduce(fixed order)
}

fn run_row_split(n: usize) -> usize {
    n
}

//! L7 violating fixture: split/reduce call sites without a
//! deterministic-reduce annotation.

fn drive(pool: &mut Pool, out: &mut [f64]) {
    pool.run_row_split(4, 8, 8, out, &noop);
    pool.inner_split_reduce(4, 100, out, &acc);
}

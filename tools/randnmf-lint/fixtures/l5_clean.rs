//! L5 conforming fixture: brackets in strings/comments don't count.

pub fn tricky<'a>(s: &'a str) -> &'a str {
    // prose with an unmatched ( bracket and } brace
    let _r = r#"raw with } and ) and ""#;
    let _c = ')';
    let _esc = '\'';
    let msg = "string with ] and } and (";
    if !msg.is_empty() && !s.is_empty() {
        s
    } else {
        "fallback"
    }
}

//! Failpoints tripwire conforming fixture: gated within 3 lines.

#[cfg(feature = "failpoints")]
pub fn trigger() {
    crate::testing::failpoints::hit("qb_after_sketch");
}

pub fn always() -> u32 {
    #[cfg(feature = "failpoints")]
    crate::testing::failpoints::hit("qb_before_solve");
    7
}

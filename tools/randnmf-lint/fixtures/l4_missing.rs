//! L4 violating fixture: a dispatch surface missing a variant.

pub enum Strategy {
    Direct,
    Blocked,
    Streaming,
}

// lint: dispatch(Strategy)
pub fn pick(s: &Strategy) -> u8 {
    match s {
        Strategy::Direct => 0,
        Strategy::Blocked => 1,
        _ => 2,
    }
}

//! L6 violating fixture: a binding is released before it is acquired.

fn release_first(pool: &mut Pool) {
    pool.release_vec(v);
    let v = pool.acquire_vec(8);
    pool.release_vec(v);
}

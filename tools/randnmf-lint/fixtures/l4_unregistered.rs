//! L4 violating fixture: a core kind enum with no registered surface.

pub enum SketchKind {
    Uniform,
    Gaussian,
    SparseSign,
    Srht,
}

pub fn uses(k: &SketchKind) -> bool {
    matches!(k, SketchKind::Srht)
}

//! Inter-procedural zero-alloc closure over the intra-tree call graph.
//!
//! L2 checks banned tokens *inside* a `// lint: zero-alloc` fn. This pass
//! extends the obligation through calls: every fn reachable from an
//! annotated fn must itself be annotated, explicitly waived with
//! `// lint: allow(zero-alloc-closure): <why>` above its declaration, or
//! transitively free of banned allocation tokens. A violation reports the
//! offending call path (`a -> b -> c`) at the root call site, plus the
//! callee location carrying the banned token.
//!
//! Resolution limits (documented in `docs/STATIC_ANALYSIS.md`):
//!
//! * call edges are followed only when the callee name resolves to
//!   exactly **one** fn definition in the scanned tree — ambiguous names
//!   are skipped rather than guessed;
//! * method-style calls (`.name(...)`) whose name shadows a common std
//!   method (`clone`, `take`, `push`, …) are not followed: the receiver
//!   type is unknown at the token level, so such edges would mis-resolve
//!   onto same-named tree fns. Path-style calls (`checkpoint::write(...)`)
//!   are still followed;
//! * trait-object, closure, and macro-expanded calls are invisible;
//! * an `// lint: allow(zero-alloc)` line waiver vouches for the whole
//!   line — its call edges are not followed either.

use std::collections::BTreeSet;

use crate::lexer::find_word;
use crate::lints::{blank_fn_decls, Finding, SourceFile, BANNED};

const KEYWORDS: [&str; 39] = [
    "if", "while", "for", "match", "return", "loop", "fn", "move", "in", "let", "else", "unsafe",
    "as", "ref", "mut", "box", "dyn", "impl", "where", "use", "pub", "crate", "self", "Self",
    "super", "async", "await", "break", "continue", "const", "static", "struct", "enum", "trait",
    "type", "mod", "extern", "true", "false",
];

/// Common std/core method names: method-style calls to these are never
/// followed as edges (see module docs).
const STD_METHODS: [&str; 38] = [
    "clone", "take", "write", "read", "flush", "next", "len", "push", "pop", "insert", "remove",
    "get", "drop", "min", "max", "abs", "sum", "new", "default", "from", "into", "lock", "borrow",
    "borrow_mut", "as_ref", "as_mut", "to_owned", "resize", "extend", "clear", "swap", "iter",
    "map", "filter", "collect", "join", "send", "recv",
];

fn is_ident_ch(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// Identifier tokens immediately followed by `(` — call sites. Skips
/// keywords, macro invocations (`name!`), and method-style std names.
fn call_names(code: &str) -> Vec<String> {
    let b = code.as_bytes();
    let n = b.len();
    let mut out = Vec::new();
    let mut i = 0;
    while i < n {
        if is_ident_ch(b[i]) {
            let start = i;
            let mut j = i;
            while j < n && is_ident_ch(b[j]) {
                j += 1;
            }
            let name = &code[start..j];
            let mut k = j;
            while k < n && b[k].is_ascii_whitespace() {
                k += 1;
            }
            let method_style = start > 0 && b[start - 1] == b'.';
            if k < n
                && b[k] == b'('
                && !KEYWORDS.contains(&name)
                && !name.as_bytes()[0].is_ascii_digit()
                && !(j < n && b[j] == b'!')
                && !(method_style && STD_METHODS.contains(&name))
            {
                out.push(name.to_string());
            }
            i = j.max(start + 1);
        } else {
            i += 1;
        }
    }
    out
}

fn fn_is_waived(f: &crate::functions::FnInfo) -> bool {
    f.annos.iter().any(|a| a.starts_with("allow(zero-alloc-closure)"))
}

fn fn_is_annotated(f: &crate::functions::FnInfo) -> bool {
    f.annos.iter().any(|a| a == "zero-alloc")
}

/// L2's line-waiver lookup (same line or contiguous comment block above).
fn line_is_waived(file: &SourceFile, body: &[usize], bi: usize) -> bool {
    let lx = &file.lx;
    if lx.comments[body[bi]].contains("allow(zero-alloc)") {
        return true;
    }
    let mut j = bi;
    while j > 0 {
        j -= 1;
        let pln = body[j];
        if !lx.masked[pln].trim().is_empty() || lx.comments[pln].is_empty() {
            return false;
        }
        if lx.comments[pln].contains("allow(zero-alloc)") {
            return true;
        }
    }
    false
}

/// First (body line, token) in `f` carrying a banned token without an
/// `allow(zero-alloc)` waiver — mirrors the L2 line rules.
fn banned_line(file: &SourceFile, f: &crate::functions::FnInfo) -> Option<(usize, &'static str)> {
    for (bi, &ln) in f.body.iter().enumerate() {
        if line_is_waived(file, &f.body, bi) {
            continue;
        }
        for tok in BANNED {
            if file.lx.masked[ln].contains(tok) {
                return Some((ln, tok));
            }
        }
    }
    None
}

struct Graph {
    /// name -> fn definitions carrying it.
    defs: std::collections::BTreeMap<String, Vec<(usize, usize)>>,
    /// per (file, fn): outgoing (callee name, call line) edges.
    edges: Vec<Vec<Vec<(String, usize)>>>,
}

fn build(files: &[SourceFile]) -> Graph {
    let mut defs: std::collections::BTreeMap<String, Vec<(usize, usize)>> =
        std::collections::BTreeMap::new();
    for (fi, file) in files.iter().enumerate() {
        for (fni, f) in file.fns.iter().enumerate() {
            defs.entry(f.name.clone()).or_default().push((fi, fni));
        }
    }
    let mut edges: Vec<Vec<Vec<(String, usize)>>> = Vec::with_capacity(files.len());
    for file in files {
        let mut per_fn = Vec::with_capacity(file.fns.len());
        for f in &file.fns {
            let mut calls: Vec<(String, usize)> = Vec::new();
            let mut seen: BTreeSet<String> = BTreeSet::new();
            for (bi, &ln) in f.body.iter().enumerate() {
                if line_is_waived(file, &f.body, bi) {
                    continue;
                }
                let code = blank_fn_decls(&file.lx.masked[ln]);
                for name in call_names(&code) {
                    if name != f.name && seen.insert(name.clone()) {
                        calls.push((name, ln));
                    }
                }
            }
            per_fn.push(calls);
        }
        edges.push(per_fn);
    }
    Graph { defs, edges }
}

/// Run the zero-alloc closure pass; findings are filed under L2.
pub fn lint_callgraph(files: &[SourceFile], findings: &mut Vec<Finding>) {
    let g = build(files);

    // DFS from each annotated root. `visited` is shared per root so
    // diamond-shaped subgraphs are walked once; `reported` dedups by
    // (root, callee, token) so one bad callee yields one finding per root.
    struct Dfs<'a> {
        files: &'a [SourceFile],
        g: &'a Graph,
        findings: &'a mut Vec<Finding>,
    }

    impl Dfs<'_> {
        #[allow(clippy::too_many_arguments)]
        fn visit(
            &mut self,
            node: (usize, usize),
            path: &mut Vec<String>,
            root_file: usize,
            root_call_line: usize,
            reported: &mut BTreeSet<(String, String, &'static str)>,
            visited: &mut BTreeSet<(usize, usize)>,
        ) {
            if !visited.insert(node) {
                return;
            }
            let (fi, fni) = node;
            let file = &self.files[fi];
            let f = &file.fns[fni];
            if let Some((ln, tok)) = banned_line(file, f) {
                let key = (path[0].clone(), f.name.clone(), tok);
                if reported.insert(key) {
                    let mut chain = path.join(" -> ");
                    chain.push_str(" -> ");
                    chain.push_str(&f.name);
                    self.findings.push(Finding {
                        path: self.files[root_file].path.clone(),
                        line: root_call_line + 1,
                        code: "L2",
                        message: format!(
                            "zero-alloc call path {chain}: `{tok}` at {}:{} \
                             (annotate the callee or waive it with \
                             `// lint: allow(zero-alloc-closure): <why>`)",
                            file.path,
                            ln + 1
                        ),
                    });
                }
                return;
            }
            path.push(f.name.clone());
            for (name, _ln) in &self.g.edges[fi][fni] {
                let Some(cands) = self.g.defs.get(name) else { continue };
                if cands.len() != 1 {
                    continue;
                }
                let (cfi, cfni) = cands[0];
                let cf = &self.files[cfi].fns[cfni];
                if fn_is_annotated(cf) || fn_is_waived(cf) {
                    continue;
                }
                self.visit((cfi, cfni), path, root_file, root_call_line, reported, visited);
            }
            path.pop();
        }
    }

    let mut dfs = Dfs { files, g: &g, findings };
    for (fi, file) in files.iter().enumerate() {
        for (fni, f) in file.fns.iter().enumerate() {
            if !fn_is_annotated(f) {
                continue;
            }
            let mut reported = BTreeSet::new();
            let mut visited: BTreeSet<(usize, usize)> = BTreeSet::new();
            visited.insert((fi, fni));
            for (name, ln) in &dfs.g.edges[fi][fni] {
                let Some(cands) = dfs.g.defs.get(name) else { continue };
                if cands.len() != 1 {
                    continue;
                }
                let (cfi, cfni) = cands[0];
                let cf = &files[cfi].fns[cfni];
                if fn_is_annotated(cf) || fn_is_waived(cf) {
                    continue;
                }
                let mut path = vec![f.name.clone()];
                dfs.visit((cfi, cfni), &mut path, fi, *ln, &mut reported, &mut visited);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(srcs: &[(&str, &str)]) -> Vec<Finding> {
        let files: Vec<SourceFile> =
            srcs.iter().map(|(p, s)| SourceFile::parse(p, s)).collect();
        let mut findings = Vec::new();
        lint_callgraph(&files, &mut findings);
        findings.sort();
        findings
    }

    #[test]
    fn transitive_alloc_reported_with_call_path() {
        let src = "\
// lint: zero-alloc
fn root(x: &[f64]) -> f64 {
    middle(x)
}

fn middle(x: &[f64]) -> f64 {
    leaf(x)
}

fn leaf(x: &[f64]) -> f64 {
    let v = x.to_vec();
    v[0]
}
";
        let f = run(&[("a.rs", src)]);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].code, "L2");
        assert_eq!(f[0].line, 3); // root's call site
        assert!(f[0].message.contains("root -> middle -> leaf"));
        assert!(f[0].message.contains("`.to_vec()` at a.rs:11"));
    }

    #[test]
    fn annotated_or_waived_callees_stop_the_walk() {
        let src = "\
// lint: zero-alloc
fn root(x: &[f64]) -> f64 {
    audited(x) + waived(x)
}

// lint: zero-alloc
fn audited(x: &[f64]) -> f64 {
    x[0]
}

// lint: allow(zero-alloc-closure): cold path, allocates by design
fn waived(x: &[f64]) -> f64 {
    x.to_vec()[0]
}
";
        assert!(run(&[("a.rs", src)]).is_empty());
    }

    #[test]
    fn ambiguous_names_are_not_followed() {
        let a = "\
// lint: zero-alloc
fn root() {
    helper();
}
";
        let b = "fn helper() { let v = vec![1]; drop(v); }\n";
        let c = "fn helper() -> u8 { 0 }\n";
        assert!(run(&[("a.rs", a), ("b.rs", b), ("c.rs", c)]).is_empty());
    }

    #[test]
    fn method_style_std_names_are_not_followed() {
        let a = "\
// lint: zero-alloc
fn root(s: &mut State) {
    s.spare.take();
}

fn take(r: &mut Reader) -> Buf {
    r.data.to_vec()
}
";
        assert!(run(&[("a.rs", a)]).is_empty());
    }

    #[test]
    fn line_waiver_suppresses_the_edge() {
        let src = "\
// lint: zero-alloc
fn root() {
    cold_init(); // lint: allow(zero-alloc): startup only
}

fn cold_init() {
    let v = Vec::new();
    drop(v);
}
";
        assert!(run(&[("a.rs", src)]).is_empty());
    }

    #[test]
    fn clean_transitive_callees_pass() {
        let src = "\
// lint: zero-alloc
fn root(x: &mut [f64]) {
    scale(x);
}

fn scale(x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= 2.0;
    }
}
";
        assert!(run(&[("a.rs", src)]).is_empty());
    }
}

//! The lint passes.
//!
//! Each pass works on the [`Lexed`] views plus the extracted fn/enum
//! regions; none of them parse Rust properly. The rules (and their
//! waiver annotations) are documented in `docs/STATIC_ANALYSIS.md`:
//!
//! * **L1** — workspace discipline: a fn that `acquire_mat`/`acquire_vec`s
//!   more than it `release_*`s, with no `recycle(...)` bulk return, leaks
//!   pool buffers. Waive with `// lint: transfers-buffers: <why>` (the
//!   buffers move out on purpose) or `// lint: allow(acquire-release): <why>`.
//! * **L2** — zero-alloc hygiene: fns annotated `// lint: zero-alloc` must
//!   not contain the banned allocation tokens. Waive a single line with a
//!   trailing `// lint: allow(zero-alloc): <why>` comment (or the same on
//!   comment-only lines immediately above it).
//! * **L3** — every `unsafe` must be covered by a `SAFETY` comment on the
//!   same line, or in the contiguous comment/attribute block above it.
//! * **L4** — dispatch exhaustiveness: a fn annotated
//!   `// lint: dispatch(EnumName)` must mention every variant of that
//!   enum in its body, and the core kind enums (`SketchKind`,
//!   `SolverKind`) must each have at least one registered surface.
//!   Also the failpoints tripwire: outside `failpoints.rs`, the
//!   `failpoints` symbol must sit within 3 lines of a
//!   `cfg(feature = "failpoints")` gate.
//! * **L5** — raw lines at most 100 columns; brackets balanced on the
//!   masked view (so strings/comments can't fake or hide imbalance).

use std::collections::BTreeMap;
use std::fmt;

use crate::functions::{collect_enums, extract_fns, ident_at, EnumInfo, FnInfo};
use crate::lexer::{find_word, lex, word_in, Lexed};

/// Tokens banned inside `// lint: zero-alloc` fns. Substring matches,
/// mirroring the warm-path audit checklist these lints replace.
pub const BANNED: [&str; 7] =
    ["Vec::new", "vec!", ".to_vec()", ".clone()", "format!", "Box::new", "String::from"];

/// Enums that must have at least one registered dispatch surface when
/// they appear in the scanned tree: a refactor that renames or re-homes
/// them must re-register its match sites, not silently drop the check.
pub const REQUIRED_DISPATCH: [&str; 2] = ["SketchKind", "SolverKind"];

/// Path segments marking determinism-scoped code (L7a): anything under
/// these directories feeds the reproducible numeric pipeline.
pub const DET_SCOPED: [&str; 5] = ["linalg/", "sketch/", "nmf/", "runtime/", "coordinator/"];

/// Unordered std collections banned in determinism-scoped paths (L7a).
pub const UNORDERED: [&str; 2] = ["HashMap", "HashSet"];

/// Split/reduce entry points whose call sites must justify a fixed
/// reduce order (L7b).
pub const REDUCE_CALLS: [&str; 2] = ["run_row_split", "inner_split_reduce"];

/// One lint finding. `line` is 1-based (editor-clickable `path:line`).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub path: String,
    pub line: usize,
    pub code: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.code, self.message)
    }
}

/// A parsed source file, ready to lint.
pub struct SourceFile {
    pub path: String,
    pub lx: Lexed,
    pub fns: Vec<FnInfo>,
    pub enums: Vec<EnumInfo>,
}

impl SourceFile {
    pub fn parse(path: &str, text: &str) -> Self {
        let lx = lex(text);
        let fns = extract_fns(&lx);
        let enums = collect_enums(&lx);
        SourceFile { path: path.to_string(), lx, fns, enums }
    }
}

/// Run every pass over the parsed files; findings come back sorted by
/// (path, line, code, message) and deduplicated.
pub fn lint(files: &[SourceFile]) -> Vec<Finding> {
    let mut findings: Vec<Finding> = Vec::new();
    // name -> (file index, enum index); later declarations shadow earlier
    // ones, which only matters if two scanned files declare the same enum.
    let mut enums: BTreeMap<&str, (usize, usize)> = BTreeMap::new();
    // (file index, fn index, target enum name)
    let mut surfaces: Vec<(usize, usize, String)> = Vec::new();

    for (fi, file) in files.iter().enumerate() {
        for (ei, e) in file.enums.iter().enumerate() {
            enums.insert(e.name.as_str(), (fi, ei));
        }
    }
    for (fi, file) in files.iter().enumerate() {
        lint_file(file, fi, &mut findings, &mut surfaces);
        crate::dataflow::lint_dataflow(file, &mut findings);
    }
    crate::callgraph::lint_callgraph(files, &mut findings);

    // L4 dispatch resolution: every registered surface must mention every
    // variant of its enum somewhere in the fn body.
    for (fi, fni, ename) in &surfaces {
        let file = &files[*fi];
        let f = &file.fns[*fni];
        let Some(&(efi, ei)) = enums.get(ename.as_str()) else {
            findings.push(Finding {
                path: file.path.clone(),
                line: f.sig_line + 1,
                code: "L4",
                message: format!("dispatch({ename}): enum not found in tree"),
            });
            continue;
        };
        let body: String = f
            .body
            .iter()
            .map(|&ln| file.lx.masked[ln].as_str())
            .collect::<Vec<_>>()
            .join("\n");
        for v in &files[efi].enums[ei].variants {
            if !word_in(&body, v) {
                findings.push(Finding {
                    path: file.path.clone(),
                    line: f.sig_line + 1,
                    code: "L4",
                    message: format!("fn {}: missing {ename}::{v}", f.name),
                });
            }
        }
    }
    // L4 minimum-surface tripwire for the core kind enums.
    for name in REQUIRED_DISPATCH {
        if let Some(&(efi, ei)) = enums.get(name) {
            if !surfaces.iter().any(|(_, _, e)| e.as_str() == name) {
                findings.push(Finding {
                    path: files[efi].path.clone(),
                    line: files[efi].enums[ei].sig_line + 1,
                    code: "L4",
                    message: format!(
                        "enum {name}: no registered dispatch surface \
                         (annotate one with `// lint: dispatch({name})`)"
                    ),
                });
            }
        }
    }

    findings.sort();
    findings.dedup();
    findings
}

fn lint_file(
    file: &SourceFile,
    fi: usize,
    findings: &mut Vec<Finding>,
    surfaces: &mut Vec<(usize, usize, String)>,
) {
    let path = &file.path;
    let lx = &file.lx;
    let report = |findings: &mut Vec<Finding>, line: usize, code: &'static str, message: String| {
        findings.push(Finding { path: path.clone(), line: line + 1, code, message });
    };

    // ---- L5a: line length (raw text — what the reader actually sees).
    for (i, line) in lx.raw.iter().enumerate() {
        let cols = line.chars().count();
        if cols > 100 {
            report(findings, i, "L5", format!("line exceeds 100 columns ({cols})"));
        }
    }

    // ---- L5b: bracket balance on the masked view. One report per file,
    // then stop — everything after a mismatch is noise.
    let mut stack: Vec<(char, usize)> = Vec::new();
    let mut broken = false;
    'outer: for (i, line) in lx.masked.iter().enumerate() {
        for ch in line.chars() {
            match ch {
                '(' | '[' | '{' => stack.push((ch, i)),
                ')' | ']' | '}' => {
                    let want = match ch {
                        ')' => '(',
                        ']' => '[',
                        _ => '{',
                    };
                    if stack.last().map(|&(c, _)| c) != Some(want) {
                        report(findings, i, "L5", format!("unbalanced bracket '{ch}'"));
                        broken = true;
                        break 'outer;
                    }
                    stack.pop();
                }
                _ => {}
            }
        }
    }
    if !broken {
        if let Some(&(ch, i)) = stack.first() {
            report(findings, i, "L5", format!("bracket '{ch}' never closed"));
        }
    }

    // ---- L3: unsafe audit.
    for (i, line) in lx.masked.iter().enumerate() {
        if !word_in(line, "unsafe") {
            continue;
        }
        if lx.comments[i].contains("SAFETY") {
            continue;
        }
        // Walk upward: through the contiguous comment/attribute chain,
        // other one-line `unsafe impl ... {}` lines (one comment may cover
        // a group), and up to 2 plain code lines (the `unsafe` may sit
        // mid-statement after a line break).
        let mut ok = false;
        let mut code_skips = 2;
        let mut j = i;
        while j > 0 {
            j -= 1;
            let com = lx.comments[j].trim();
            let code = lx.masked[j].trim();
            if !com.is_empty() && (com.contains("SAFETY") || com.contains("# Safety")) {
                ok = true;
                break;
            }
            if !com.is_empty() && code.is_empty() {
                continue;
            }
            if code.starts_with("#[") || code.starts_with("#![") {
                continue;
            }
            if word_in(code, "unsafe") && code.ends_with("{}") {
                continue;
            }
            if !code.is_empty() && code_skips > 0 {
                code_skips -= 1;
                continue;
            }
            break;
        }
        if !ok {
            report(findings, i, "L3", "unsafe not preceded by a SAFETY comment".to_string());
        }
    }

    // ---- Failpoints tripwire (filed under L4). The symbol is detected on
    // MASKED text (doc-comment mentions don't count); the guard is
    // detected on RAW text (the feature name lives inside a string
    // literal, which masking blanks).
    if !path.ends_with("failpoints.rs") {
        let guard = "cfg(feature = \"failpoints\")";
        for (i, line) in lx.masked.iter().enumerate() {
            if !word_in(line, "failpoints") {
                continue;
            }
            let lo = i.saturating_sub(3);
            if !lx.raw[lo..=i].iter().any(|r| r.contains(guard)) {
                report(
                    findings,
                    i,
                    "L4",
                    "failpoints symbol not cfg-gated within 3 lines".to_string(),
                );
            }
        }
    }

    // ---- L7: determinism rules.
    lint_determinism(file, findings);

    // ---- Per-fn lints.
    for (fni, f) in file.fns.iter().enumerate() {
        // L1 workspace discipline: acquires balanced by releases/recycle.
        let mut acq = 0usize;
        let mut rel = 0usize;
        let mut rec = 0usize;
        for &ln in &f.body {
            // Don't count the fn's own declaration as a call.
            let code = blank_fn_decls(&lx.masked[ln]);
            acq += count_calls(&code, &["acquire_mat", "acquire_vec"]);
            rel += count_calls(&code, &["release_mat", "release_vec"]);
            rec += count_calls(&code, &["recycle"]);
        }
        let waived = f.annos.iter().any(|a| {
            a.starts_with("transfers-buffers") || a.starts_with("allow(acquire-release)")
        });
        if acq > rel && rec == 0 && !waived {
            report(
                findings,
                f.sig_line,
                "L1",
                format!(
                    "fn {}: {acq} acquire(s) vs {rel} release(s), no recycle \
                     (annotate `// lint: transfers-buffers: <why>` if ownership moves out)",
                    f.name
                ),
            );
        }

        // L2 zero-alloc hygiene.
        if f.annos.iter().any(|a| a == "zero-alloc") {
            for (bi, &ln) in f.body.iter().enumerate() {
                let mut waived = lx.comments[ln].contains("allow(zero-alloc)");
                // Also honor a waiver on comment-only lines immediately
                // above the flagged line.
                let mut j = bi;
                while !waived && j > 0 {
                    j -= 1;
                    let pln = f.body[j];
                    if !lx.masked[pln].trim().is_empty() || lx.comments[pln].is_empty() {
                        break;
                    }
                    if lx.comments[pln].contains("allow(zero-alloc)") {
                        waived = true;
                    }
                }
                if waived {
                    continue;
                }
                for tok in BANNED {
                    if lx.masked[ln].contains(tok) {
                        report(
                            findings,
                            ln,
                            "L2",
                            format!("fn {}: `{tok}` in zero-alloc fn", f.name),
                        );
                    }
                }
            }
        }

        // L4 dispatch surface registration (resolved once all files are
        // parsed, so the enum may live in another file).
        for a in &f.annos {
            if let Some(ename) = dispatch_target(a) {
                surfaces.push((fi, fni, ename.to_string()));
            }
        }
    }
}

/// L7 — determinism rules.
///
/// * **L7a**: `HashMap`/`HashSet` are banned in determinism-scoped paths
///   ([`DET_SCOPED`]) — iteration order is unordered, and float
///   accumulation over an unordered collection is non-reproducible.
///   Waive a line with `// lint: allow(determinism): <why>`.
/// * **L7b**: every `run_row_split` / `inner_split_reduce` call site must
///   carry a `// lint: deterministic-reduce(<reason>)` annotation (same
///   line or the contiguous comment block above) naming why its reduce
///   order is fixed.
fn lint_determinism(file: &SourceFile, findings: &mut Vec<Finding>) {
    let lx = &file.lx;
    let mut report = |line: usize, message: String| {
        findings.push(Finding { path: file.path.clone(), line: line + 1, code: "L7", message });
    };

    // Same-line or contiguous comment/attribute block above the call.
    let line_waived = |i: usize, marker: &str| -> bool {
        if lx.comments[i].contains(marker) {
            return true;
        }
        let mut j = i;
        while j > 0 {
            j -= 1;
            let code = lx.masked[j].trim();
            let com = &lx.comments[j];
            if !com.is_empty() && code.is_empty() {
                if com.contains(marker) {
                    return true;
                }
                continue;
            }
            if code.starts_with("#[") || code.starts_with("#![") {
                continue;
            }
            return false;
        }
        false
    };

    let det_path = {
        let p = file.path.replace('\\', "/");
        DET_SCOPED.iter().any(|seg| p.contains(seg))
    };
    if det_path {
        for (i, line) in lx.masked.iter().enumerate() {
            for ty in UNORDERED {
                if find_word(line, ty).is_some() && !line_waived(i, "allow(determinism)") {
                    report(
                        i,
                        format!(
                            "`{ty}` in a determinism-scoped path (unordered iteration; \
                             use BTreeMap/BTreeSet or waive: \
                             `// lint: allow(determinism): <why>`)"
                        ),
                    );
                }
            }
        }
    }

    for (i, line) in lx.masked.iter().enumerate() {
        let code = blank_fn_decls(line);
        for name in REDUCE_CALLS {
            if count_calls(&code, &[name]) > 0 && !line_waived(i, "deterministic-reduce(") {
                report(
                    i,
                    format!(
                        "`{name}` call site lacks a `// lint: deterministic-reduce(<reason>)` \
                         annotation naming why its reduce order is fixed"
                    ),
                );
            }
        }
    }
}

/// `dispatch(EnumName)` annotation → `EnumName`.
fn dispatch_target(anno: &str) -> Option<&str> {
    let rest = anno.strip_prefix("dispatch(")?;
    let name = ident_at(rest, 0);
    if !name.is_empty() && rest[name.len()..].starts_with(')') {
        Some(name)
    } else {
        None
    }
}

/// Blank every `fn <name>` declaration on the line so the name is not
/// counted as a call by [`count_calls`].
pub(crate) fn blank_fn_decls(line: &str) -> String {
    let mut chars: Vec<char> = line.chars().collect();
    let mut i = 0;
    while i < chars.len() {
        let word_fn = chars[i] == 'f'
            && i + 1 < chars.len()
            && chars[i + 1] == 'n'
            && (i == 0 || !is_ident(chars[i - 1]))
            && (i + 2 >= chars.len() || !is_ident(chars[i + 2]));
        if word_fn {
            let mut j = i + 2;
            while j < chars.len() && chars[j].is_whitespace() {
                j += 1;
            }
            if j > i + 2 && j < chars.len() && is_ident(chars[j]) {
                while j < chars.len() && is_ident(chars[j]) {
                    j += 1;
                }
                for c in chars[i..j].iter_mut() {
                    *c = ' ';
                }
                i = j;
                continue;
            }
        }
        i += 1;
    }
    chars.into_iter().collect()
}

fn is_ident(c: char) -> bool {
    c == '_' || c.is_alphanumeric()
}

/// Count call sites: a word-boundary occurrence of any `name`, followed
/// by optional whitespace and `(`.
pub(crate) fn count_calls(code: &str, names: &[&str]) -> usize {
    let mut total = 0;
    for name in names {
        let mut base = 0;
        while let Some(rel) = find_word(&code[base..], name) {
            let at = base + rel;
            if code[at + name.len()..].trim_start().starts_with('(') {
                total += 1;
            }
            base = at + name.len();
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_one(src: &str) -> Vec<Finding> {
        lint(&[SourceFile::parse("test.rs", src)])
    }

    #[test]
    fn l1_flags_leak_and_honors_waiver() {
        let leak = "\
fn leaky(pool: &mut Pool) {
    let a = pool.acquire_mat(4, 4);
    let b = pool.acquire_vec(4);
    pool.release_vec(b);
}
";
        let f = run_one(leak);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].code, "L1");
        assert_eq!(f[0].line, 1);
        assert!(f[0].message.contains("2 acquire(s) vs 1 release(s)"));

        let waived = "\
// lint: transfers-buffers: caller owns the result
fn mover(pool: &mut Pool) -> Mat {
    pool.acquire_mat(4, 4)
}
";
        assert!(run_one(waived).is_empty());

        let recycled = "\
fn bulk(pool: &mut Pool) {
    let a = pool.acquire_mat(4, 4);
    let b = pool.acquire_mat(4, 4);
    pool.recycle(&mut [a, b]);
}
";
        assert!(run_one(recycled).is_empty());
    }

    #[test]
    fn l1_does_not_count_declarations_as_calls() {
        let src = "\
fn acquire_mat(pool: &mut Pool) -> Mat {
    pool.make()
}
";
        assert!(run_one(src).is_empty());
    }

    #[test]
    fn l2_flags_banned_tokens_only_in_annotated_fns() {
        let src = "\
// lint: zero-alloc
fn hot(xs: &[f64]) -> Vec<f64> {
    let v = xs.to_vec();
    v
}

fn cold(xs: &[f64]) -> Vec<f64> {
    xs.to_vec()
}
";
        let f = run_one(src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].code, "L2");
        assert_eq!(f[0].line, 3);
        assert!(f[0].message.contains(".to_vec()"));
    }

    #[test]
    fn l2_waiver_on_same_line_and_above() {
        let src = "\
// lint: zero-alloc
fn hot(n: usize) {
    let a = Vec::new(); // lint: allow(zero-alloc): empty vec, no alloc
    // lint: allow(zero-alloc): justified on the
    // preceding comment lines
    let b = Vec::new();
    drop((a, b, n));
}
";
        assert!(run_one(src).is_empty());
    }

    #[test]
    fn l3_unsafe_needs_safety_comment() {
        let bare = "\
fn f(p: *const f64) -> f64 {
    unsafe { *p }
}
";
        let f = run_one(bare);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].code, "L3");
        assert_eq!(f[0].line, 2);

        let audited = "\
fn f(p: *const f64) -> f64 {
    // SAFETY: caller guarantees p is valid.
    unsafe { *p }
}
";
        assert!(run_one(audited).is_empty());

        let same_line = "\
fn f(p: *const f64) -> f64 {
    unsafe { *p } // SAFETY: caller guarantees p is valid.
}
";
        assert!(run_one(same_line).is_empty());
    }

    #[test]
    fn l4_dispatch_missing_variant() {
        let src = "\
pub enum Kind {
    Alpha,
    Beta,
}

// lint: dispatch(Kind)
fn pick(k: Kind) -> u8 {
    match k {
        Kind::Alpha => 0,
        _ => 1,
    }
}
";
        let f = run_one(src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].code, "L4");
        assert_eq!(f[0].line, 7);
        assert!(f[0].message.contains("missing Kind::Beta"));
    }

    #[test]
    fn l4_required_enums_need_a_surface() {
        let src = "\
pub enum SketchKind {
    Uniform,
    Gaussian,
}
";
        let f = run_one(src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].code, "L4");
        assert_eq!(f[0].line, 1);
        assert!(f[0].message.contains("no registered dispatch surface"));
    }

    #[test]
    fn failpoints_symbol_must_be_gated() {
        let gated = "\
#[cfg(feature = \"failpoints\")]
use crate::testing::failpoints;
";
        assert!(run_one(gated).is_empty());

        let bare = "\
use crate::testing::failpoints;
";
        let f = run_one(bare);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].code, "L4");
        assert!(f[0].message.contains("not cfg-gated"));
    }

    #[test]
    fn l7_unordered_collections_scoped_to_det_paths() {
        let src =
            "use std::collections::HashMap;\nfn f(m: &HashMap<u32, f64>) -> usize { m.len() }\n";
        // Determinism-scoped path: both mentions flagged.
        let f = lint(&[SourceFile::parse("rust/src/runtime/registry.rs", src)]);
        let l7: Vec<_> = f.iter().filter(|w| w.code == "L7").collect();
        assert_eq!(l7.len(), 2);
        assert!(l7[0].message.contains("`HashMap` in a determinism-scoped path"));
        // Outside the scoped paths: clean.
        let f = lint(&[SourceFile::parse("rust/src/io/loader.rs", src)]);
        assert!(f.iter().all(|w| w.code != "L7"));
    }

    #[test]
    fn l7_determinism_waiver() {
        let src = "\
// lint: allow(determinism): keys are read once, order never observed
use std::collections::HashMap;
fn f(m: &HashMap<u32, f64>) -> usize {
    // lint: allow(determinism): len() is order-free
    m.len()
}
";
        let f = lint(&[SourceFile::parse("rust/src/runtime/registry.rs", src)]);
        // line 3 (the fn signature mention) has no waiver and still fires
        let l7: Vec<_> = f.iter().filter(|w| w.code == "L7").collect();
        assert_eq!(l7.len(), 1);
        assert_eq!(l7[0].line, 3);
    }

    #[test]
    fn l7_reduce_call_sites_need_annotation() {
        let bare = "\
fn f(pool: &mut Pool) {
    pool.run_row_split(8, |r| r.sum());
}
";
        let f = run_one(bare);
        assert_eq!(f.iter().filter(|w| w.code == "L7").count(), 1);
        assert!(f
            .iter()
            .any(|w| w.code == "L7" && w.message.contains("`run_row_split` call site lacks")));

        let annotated = "\
fn f(pool: &mut Pool) {
    // lint: deterministic-reduce(row chunks joined in index order)
    pool.run_row_split(8, |r| r.sum());
    pool.inner_split_reduce(4, acc); // lint: deterministic-reduce(fixed tree)
}
";
        assert!(run_one(annotated).iter().all(|w| w.code != "L7"));
        // The definition of the entry point itself is not a call site.
        let decl = "fn run_row_split(n: usize) -> usize {\n    n\n}\n";
        assert!(run_one(decl).iter().all(|w| w.code != "L7"));
    }

    #[test]
    fn l5_long_lines_and_brackets() {
        let long = format!("fn f() {{ let x = 1; }} // {}\n", "x".repeat(100));
        let f = run_one(&long);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].code, "L5");
        assert!(f[0].message.contains("exceeds 100 columns"));

        let unbalanced = "fn f() { (]\n}\n";
        let f = run_one(unbalanced);
        assert!(f.iter().any(|w| w.code == "L5" && w.message.contains("unbalanced")));

        let unclosed = "fn f() {\n";
        let f = run_one(unclosed);
        assert!(f.iter().any(|w| w.code == "L5" && w.message.contains("never closed")));
    }

    #[test]
    fn l5_ignores_brackets_in_strings_and_comments() {
        let src = "\
fn f() -> &'static str {
    // an ( unmatched bracket in prose
    \"}{)(\"
}
";
        assert!(run_one(src).is_empty());
    }
}

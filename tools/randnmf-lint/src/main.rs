//! CLI entry point. `randnmf-lint [PATH...]` — defaults to `rust/src`
//! (run from the repo root, as CI does).

use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let roots = if args.is_empty() {
        vec!["rust/src".to_string()]
    } else {
        args
    };
    match randnmf_lint::run(&roots) {
        Ok(report) => {
            for f in &report.findings {
                println!("{f}");
            }
            eprintln!("-- {} findings over {} files", report.findings.len(), report.files_scanned);
            if report.findings.is_empty() {
                ExitCode::SUCCESS
            } else {
                ExitCode::from(1)
            }
        }
        Err(e) => {
            eprintln!("randnmf-lint: {e}");
            ExitCode::from(2)
        }
    }
}

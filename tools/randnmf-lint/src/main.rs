//! CLI entry point. `randnmf-lint [--format text|sarif] [PATH...]` —
//! defaults to `rust/src` (run from the repo root, as CI does).

use std::process::ExitCode;

fn main() -> ExitCode {
    let mut format = String::from("text");
    let mut roots: Vec<String> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--format" {
            match args.next() {
                Some(f) if f == "text" || f == "sarif" => format = f,
                Some(f) => {
                    eprintln!("randnmf-lint: unknown format `{f}` (expected text|sarif)");
                    return ExitCode::from(2);
                }
                None => {
                    eprintln!("randnmf-lint: --format requires a value (text|sarif)");
                    return ExitCode::from(2);
                }
            }
        } else if let Some(f) = a.strip_prefix("--format=") {
            if f == "text" || f == "sarif" {
                format = f.to_string();
            } else {
                eprintln!("randnmf-lint: unknown format `{f}` (expected text|sarif)");
                return ExitCode::from(2);
            }
        } else {
            roots.push(a);
        }
    }
    if roots.is_empty() {
        roots.push("rust/src".to_string());
    }
    match randnmf_lint::run(&roots) {
        Ok(report) => {
            if format == "sarif" {
                print!("{}", randnmf_lint::to_sarif(&report.findings));
            } else {
                for f in &report.findings {
                    println!("{f}");
                }
            }
            eprintln!("-- {} findings over {} files", report.findings.len(), report.files_scanned);
            if report.findings.is_empty() {
                ExitCode::SUCCESS
            } else {
                ExitCode::from(1)
            }
        }
        Err(e) => {
            eprintln!("randnmf-lint: {e}");
            ExitCode::from(2)
        }
    }
}

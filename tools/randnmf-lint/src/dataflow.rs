//! L6 — per-binding workspace-buffer dataflow.
//!
//! L1 checks that acquire/release *counts* balance per fn. This pass
//! tracks each binding through the acquire → release lifecycle and
//! catches what counting cannot:
//!
//! * **double release** — `release_mat(a)` twice for one acquire;
//! * **release before acquire** — the release textually precedes every
//!   acquire of that binding;
//! * **kind mismatch** — acquired with `acquire_mat` but returned with
//!   `release_vec` (or vice versa);
//! * **early-exit leaks** — a `return` or `?` while acquired buffers are
//!   outstanding silently drops them on the error path (the pool never
//!   gets them back). Waive a deliberate site with a trailing
//!   `// lint: allow(leak-on-error): <why>`;
//! * **per-binding leak** — a binding acquired and never released even
//!   though the fn-level totals balance (two releases of `b` masking zero
//!   releases of `a`), where L1 stays silent.
//!
//! The analysis is conservative by design: bindings are simple `a.b.c`
//! paths read off the assignment (`let m = pool.acquire_mat(...)`) or the
//! first call argument (`pool.release_mat(m)`). Anything harder — tuple
//! destructuring, bindings built by macros, releases through collections —
//! degrades to the anonymous counter, where only L1's totals apply.
//! `// lint: transfers-buffers:` / `// lint: allow(acquire-release):`
//! waive the whole fn; a `recycle(...)` bulk return waives the per-binding
//! end-of-fn leak check (L6e) but NOT the early-exit checks — `recycle`
//! on the success path does not run when `?` propagates an error.

use crate::lexer::find_word;
use crate::lints::{blank_fn_decls, count_calls, Finding, SourceFile};

/// (call token, buffer kind, is_acquire)
const CALLS: [(&str, &str, bool); 4] = [
    ("acquire_mat", "mat", true),
    ("acquire_vec", "vec", true),
    ("release_mat", "mat", false),
    ("release_vec", "vec", false),
];

/// Same-line or contiguous-comment-block-above waiver, mirroring L2's
/// line-waiver lookup but scoped to the fn body.
fn line_waived(file: &SourceFile, body: &[usize], bi: usize, marker: &str) -> bool {
    let lx = &file.lx;
    if lx.comments[body[bi]].contains(marker) {
        return true;
    }
    let mut j = bi;
    while j > 0 {
        j -= 1;
        let pln = body[j];
        if !lx.masked[pln].trim().is_empty() || lx.comments[pln].is_empty() {
            return false;
        }
        if lx.comments[pln].contains(marker) {
            return true;
        }
    }
    false
}

fn is_ident_byte(b: u8) -> bool {
    b == b'_' || b == b'.' || b.is_ascii_alphanumeric()
}

/// Identifier/path ending at byte `end` (exclusive), walking back over
/// ident chars and dots.
fn ident_back(s: &[u8], end: usize) -> String {
    let mut start = end;
    while start > 0 && is_ident_byte(s[start - 1]) {
        start -= 1;
    }
    String::from_utf8_lossy(&s[start..end]).into_owned()
}

/// First argument of the call whose `(` is at byte `open_paren`, if it is
/// a simple path (idents, dots, optional leading `&` / `&mut`). `None`
/// for anything more complex — those degrade to anonymous counting.
fn first_arg(code: &str, open_paren: usize) -> Option<String> {
    let b = code.as_bytes();
    let mut depth = 0usize;
    let mut i = open_paren;
    let start = open_paren + 1;
    let mut end = None;
    while i < b.len() {
        match b[i] {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    end = Some(i);
                    break;
                }
            }
            b',' if depth == 1 => {
                end = Some(i);
                break;
            }
            _ => {}
        }
        i += 1;
    }
    let end = end?;
    let mut arg = String::from_utf8_lossy(&b[start..end]).trim().to_string();
    for pre in ["&mut ", "&"] {
        if let Some(rest) = arg.strip_prefix(pre) {
            arg = rest.trim().to_string();
            break;
        }
    }
    if arg.is_empty() || !arg.bytes().all(is_ident_byte) {
        return None;
    }
    Some(arg)
}

/// Binding a `<binding> = ... acquire_*(...)` assigns to. Looks left of
/// the `=` on the same line, or on the previous line when the statement
/// wraps (previous line ending with `=`).
fn binding_of_acquire(lines: &[String], li: usize, at: usize) -> Option<String> {
    let code = lines[li].as_bytes();
    let mut eq: Option<usize> = None;
    let mut i = at;
    while i > 0 {
        i -= 1;
        match code[i] {
            b'=' => {
                // `==`, `!=`, `<=`, `+=`, … are comparisons/compound ops,
                // not assignments a binding can be read off.
                if i > 0 && b"=!<>+-*/%&|^".contains(&code[i - 1]) {
                    return None;
                }
                if i + 1 < code.len() && code[i + 1] == b'=' {
                    return None;
                }
                eq = Some(i);
                break;
            }
            b';' => break,
            _ => {}
        }
    }
    let (line, eq) = match eq {
        Some(e) => (code, e),
        None => {
            if li == 0 {
                return None;
            }
            let prev = lines[li - 1].trim_end();
            if !prev.ends_with('=') {
                return None;
            }
            (prev.as_bytes(), prev.len() - 1)
        }
    };
    let mut j = eq;
    while j > 0 && line[j - 1].is_ascii_whitespace() {
        j -= 1;
    }
    let name = ident_back(line, j);
    if name.is_empty() || name.contains('.') {
        return None;
    }
    Some(name)
}

/// Byte offsets of word-boundary immediately-called occurrences of
/// `name` in `code`: `(match start, '(' position)` pairs.
fn find_calls(code: &str, name: &str) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut base = 0;
    while let Some(rel) = find_word(&code[base..], name) {
        let at = base + rel;
        let rest = &code[at + name.len()..];
        let stripped = rest.trim_start();
        if stripped.starts_with('(') {
            out.push((at, at + name.len() + (rest.len() - stripped.len())));
        }
        base = at + name.len();
    }
    out
}

#[derive(Clone)]
struct Event {
    bi: usize,
    is_acq: bool,
    kind: &'static str,
    binding: Option<String>,
}

fn analyze_fn(file: &SourceFile, f: &crate::functions::FnInfo, findings: &mut Vec<Finding>) {
    let mut report = |ln: usize, msg: String| {
        findings.push(Finding { path: file.path.clone(), line: ln + 1, code: "L6", message: msg });
    };
    let waived = f.annos.iter().any(|a| {
        a.starts_with("transfers-buffers") || a.starts_with("allow(acquire-release)")
    });
    let lines: Vec<String> =
        f.body.iter().map(|&ln| blank_fn_decls(&file.lx.masked[ln])).collect();
    let has_recycle = lines.iter().any(|c| count_calls(c, &["recycle"]) > 0);

    // Pass 1: collect acquire/release events in textual order.
    let mut events: Vec<Event> = Vec::new();
    for (bi, code) in lines.iter().enumerate() {
        let mut evs: Vec<(usize, bool, &'static str, usize)> = Vec::new();
        for (name, kind, is_acq) in CALLS {
            for (at, op) in find_calls(code, name) {
                evs.push((at, is_acq, kind, op));
            }
        }
        evs.sort();
        for (at, is_acq, kind, op) in evs {
            let binding = if is_acq {
                binding_of_acquire(&lines, bi, at)
            } else {
                first_arg(code, op)
            };
            events.push(Event { bi, is_acq, kind, binding });
        }
    }

    // binding -> body indices of its acquires (for before/after ordering).
    let mut acquires: std::collections::BTreeMap<&str, Vec<usize>> =
        std::collections::BTreeMap::new();
    for e in &events {
        if e.is_acq {
            if let Some(b) = &e.binding {
                acquires.entry(b.as_str()).or_default().push(e.bi);
            }
        }
    }
    let total_acq = events.iter().filter(|e| e.is_acq).count();
    let total_rel = events.len() - total_acq;

    // Pass 2: walk lines and events, tracking per-binding availability.
    // binding -> (outstanding count, kind it was acquired as)
    let mut avail: std::collections::BTreeMap<String, (usize, Option<&'static str>)> =
        std::collections::BTreeMap::new();
    let mut anon = 0usize;
    let mut ei = 0usize;
    for (bi, code) in lines.iter().enumerate() {
        // L6d: early-return / `?` leak checks run per line, before the
        // line's own events (a `return` line never releases first).
        if !waived {
            let outstanding: Vec<&str> =
                avail.iter().filter(|(_, (c, _))| *c > 0).map(|(b, _)| b.as_str()).collect();
            if !outstanding.is_empty() || anon > 0 {
                let is_tail = bi + 1 == lines.len();
                let early_return = !is_tail && find_word(code, "return").is_some();
                let try_op = code.contains('?');
                if (early_return || (try_op && !is_tail))
                    && !line_waived(file, &f.body, bi, "allow(leak-on-error)")
                {
                    let what = if outstanding.is_empty() {
                        "buffer(s)".to_string()
                    } else {
                        outstanding.join(", ")
                    };
                    let via = if early_return { "return" } else { "`?`" };
                    report(
                        f.body[bi],
                        format!(
                            "fn {}: early {via} leaks acquired {what} \
                             (release before propagating, or waive the fn)",
                            f.name
                        ),
                    );
                }
            }
        }
        while ei < events.len() && events[ei].bi == bi {
            let e = events[ei].clone();
            ei += 1;
            let Some(b) = e.binding else {
                if e.is_acq {
                    anon += 1;
                } else {
                    anon = anon.saturating_sub(1);
                }
                continue;
            };
            if e.is_acq {
                let entry = avail.entry(b).or_insert((0, None));
                entry.0 += 1;
                entry.1 = Some(e.kind);
            } else {
                let (c, k) = avail.get(&b).copied().unwrap_or((0, None));
                if c > 0 {
                    if let Some(k) = k {
                        if k != e.kind {
                            report(
                                f.body[bi],
                                format!(
                                    "fn {}: `{b}` acquired as {k} but released as {}",
                                    f.name, e.kind
                                ),
                            );
                        }
                    }
                    avail.insert(b, (c - 1, k));
                } else if let Some(acqs) = acquires.get(b.as_str()) {
                    if acqs.iter().any(|&a| a > bi) && !acqs.iter().any(|&a| a <= bi) {
                        report(
                            f.body[bi],
                            format!("fn {}: `{b}` released before it is acquired", f.name),
                        );
                    } else {
                        report(f.body[bi], format!("fn {}: `{b}` released twice", f.name));
                    }
                }
                // Releases of bindings never acquired here are caller-owned
                // buffers being returned to the pool: legitimate.
            }
        }
    }

    // L6e: per-binding leak when the fn-level totals balance (L1 silent).
    if !waived && !has_recycle && total_acq == total_rel {
        for (b, (c, _)) in &avail {
            if *c > 0 {
                if let Some(acqs) = acquires.get(b.as_str()) {
                    report(
                        f.body[acqs[0]],
                        format!("fn {}: `{b}` acquired here is never released", f.name),
                    );
                }
            }
        }
    }
}

/// Run the L6 dataflow pass over one file.
pub fn lint_dataflow(file: &SourceFile, findings: &mut Vec<Finding>) {
    for f in &file.fns {
        analyze_fn(file, f, findings);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_one(src: &str) -> Vec<Finding> {
        let file = SourceFile::parse("test.rs", src);
        let mut findings = Vec::new();
        lint_dataflow(&file, &mut findings);
        findings.sort();
        findings
    }

    #[test]
    fn double_release_is_flagged() {
        let src = "\
fn f(pool: &mut Pool) {
    let a = pool.acquire_mat(4, 4);
    pool.release_mat(a);
    pool.release_mat(a);
}
";
        let f = run_one(src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 4);
        assert!(f[0].message.contains("`a` released twice"));
    }

    #[test]
    fn release_before_acquire_is_flagged() {
        let src = "\
fn f(pool: &mut Pool) {
    pool.release_mat(a);
    let a = pool.acquire_mat(4, 4);
    pool.release_mat(a);
}
";
        let f = run_one(src);
        // one ordering finding; the trailing release balances the acquire
        assert!(f.iter().any(|w| w.line == 2 && w.message.contains("released before")));
    }

    #[test]
    fn kind_mismatch_is_flagged() {
        let src = "\
fn f(pool: &mut Pool) {
    let a = pool.acquire_mat(4, 4);
    pool.release_vec(a);
}
";
        let f = run_one(src);
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("acquired as mat but released as vec"));
    }

    #[test]
    fn early_try_leak_and_waiver() {
        let src = "\
fn f(pool: &mut Pool) -> Result<(), E> {
    let a = pool.acquire_mat(4, 4);
    step()?;
    pool.release_mat(a);
    Ok(())
}
";
        let f = run_one(src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 3);
        assert!(f[0].message.contains("early `?` leaks acquired a"));

        let waived = "\
fn f(pool: &mut Pool) -> Result<(), E> {
    let a = pool.acquire_mat(4, 4);
    step()?; // lint: allow(leak-on-error): pool rebuilt on error path
    pool.release_mat(a);
    Ok(())
}
";
        assert!(run_one(waived).is_empty());
    }

    #[test]
    fn early_return_leak() {
        let src = "\
fn f(pool: &mut Pool, bail: bool) {
    let a = pool.acquire_vec(8);
    if bail {
        return;
    }
    pool.release_vec(a);
}
";
        let f = run_one(src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 4);
        assert!(f[0].message.contains("early return leaks acquired a"));
    }

    #[test]
    fn per_binding_leak_with_balanced_totals() {
        let src = "\
fn f(pool: &mut Pool) {
    let a = pool.acquire_vec(8);
    let b = pool.acquire_vec(8);
    pool.release_vec(b);
    pool.release_vec(b);
}
";
        let f = run_one(src);
        assert!(f.iter().any(|w| w.message.contains("`b` released twice")));
        assert!(f
            .iter()
            .any(|w| w.line == 2 && w.message.contains("`a` acquired here is never released")));
    }

    #[test]
    fn caller_owned_release_and_recycle_are_clean() {
        let src = "\
fn f(pool: &mut Pool, m: Mat) {
    pool.release_mat(m);
}

fn g(pool: &mut Pool) {
    let a = pool.acquire_mat(4, 4);
    let b = pool.acquire_mat(4, 4);
    pool.recycle(&mut [a, b]);
}
";
        assert!(run_one(src).is_empty());
    }

    #[test]
    fn transfers_buffers_waives_the_fn() {
        let src = "\
// lint: transfers-buffers: ownership moves into the model
fn f(pool: &mut Pool) -> Result<Mat, E> {
    let a = pool.acquire_mat(4, 4);
    step()?;
    Ok(a)
}
";
        assert!(run_one(src).is_empty());
    }
}

//! Comment/string-aware line lexer.
//!
//! The lints never parse Rust properly — they work on three parallel
//! per-line views of a source file:
//!
//! * **raw** — the line exactly as written (line-length checks, and the
//!   failpoints guard, whose feature name lives inside a string literal);
//! * **masked** — comments, string/char literals and their contents
//!   blanked to spaces, so token searches and brace matching never match
//!   inside prose or data;
//! * **comments** — the concatenated comment text of the line (line
//!   comments, doc comments, and block-comment interiors), which is where
//!   `// lint:` annotations and `// SAFETY:` audits live.
//!
//! The state machine understands line comments, nested block comments,
//! string literals with escapes, byte strings, raw strings (`r"…"`,
//! `r#"…"#`, any hash depth), char literals, and the lifetime-vs-char
//! ambiguity (`'a` vs `'a'`). It does not understand raw identifiers
//! (`r#fn`) — the tree doesn't use them.

/// Per-line views of one source file. All three vectors have the same
/// length (one entry per line).
pub struct Lexed {
    pub raw: Vec<String>,
    pub masked: Vec<String>,
    pub comments: Vec<String>,
}

#[derive(PartialEq)]
enum State {
    Normal,
    Line,
    Block,
    Str,
    RawStr,
    Char,
}

/// Lex `text` into per-line raw/masked/comment views.
pub fn lex(text: &str) -> Lexed {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let raw: Vec<String> = text.split('\n').map(str::to_string).collect();

    let mut masked = Vec::with_capacity(raw.len());
    let mut comments = Vec::with_capacity(raw.len());
    let mut line_out = String::new();
    let mut line_com = String::new();

    let mut state = State::Normal;
    let mut depth = 0usize; // block-comment nesting
    let mut hashes = 0usize; // raw-string hash count
    let mut i = 0usize;

    while i < n {
        let c = chars[i];
        let nxt = if i + 1 < n { chars[i + 1] } else { '\0' };
        if c == '\n' {
            if state == State::Line {
                state = State::Normal;
            }
            masked.push(std::mem::take(&mut line_out));
            comments.push(std::mem::take(&mut line_com));
            i += 1;
            continue;
        }
        match state {
            State::Normal => {
                if c == '/' && nxt == '/' {
                    state = State::Line;
                    line_out.push_str("  ");
                    i += 2;
                } else if c == '/' && nxt == '*' {
                    state = State::Block;
                    depth = 1;
                    line_out.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    state = State::Str;
                    line_out.push(' ');
                    i += 1;
                } else if c == 'r' && (nxt == '"' || nxt == '#') {
                    // raw string r"…" or r#"…"# (any hash depth)
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && chars[j] == '#' {
                        h += 1;
                        j += 1;
                    }
                    if j < n && chars[j] == '"' {
                        state = State::RawStr;
                        hashes = h;
                        for _ in i..=j {
                            line_out.push(' ');
                        }
                        i = j + 1;
                    } else {
                        line_out.push(c);
                        i += 1;
                    }
                } else if c == 'b' && nxt == '"' {
                    state = State::Str;
                    line_out.push_str("  ");
                    i += 2;
                } else if c == '\'' {
                    // lifetime ('a not followed by a closing quote) or char
                    let is_lifetime = (nxt.is_alphanumeric() || nxt == '_')
                        && !(i + 2 < n && chars[i + 2] == '\'');
                    if is_lifetime {
                        line_out.push(c);
                        i += 1;
                    } else {
                        state = State::Char;
                        line_out.push(' ');
                        i += 1;
                    }
                } else {
                    line_out.push(c);
                    i += 1;
                }
            }
            State::Line => {
                line_com.push(c);
                line_out.push(' ');
                i += 1;
            }
            State::Block => {
                if c == '*' && nxt == '/' {
                    depth -= 1;
                    line_out.push_str("  ");
                    i += 2;
                    if depth == 0 {
                        state = State::Normal;
                    }
                } else if c == '/' && nxt == '*' {
                    depth += 1;
                    line_out.push_str("  ");
                    i += 2;
                } else {
                    line_com.push(c);
                    line_out.push(' ');
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    // A backslash escaping the newline (string continuation)
                    // must NOT swallow it: the `\n` has to reach the top of
                    // the loop so the per-line vectors stay in sync.
                    if nxt == '\n' {
                        line_out.push(' ');
                        i += 1;
                    } else {
                        line_out.push_str("  ");
                        i += 2;
                    }
                } else {
                    if c == '"' {
                        state = State::Normal;
                    }
                    line_out.push(' ');
                    i += 1;
                }
            }
            State::RawStr => {
                if c == '"' {
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && chars[j] == '#' && h < hashes {
                        h += 1;
                        j += 1;
                    }
                    if h == hashes {
                        state = State::Normal;
                        for _ in 0..=h {
                            line_out.push(' ');
                        }
                        i = j;
                        continue;
                    }
                }
                line_out.push(' ');
                i += 1;
            }
            State::Char => {
                if c == '\\' {
                    // Same newline guard as Str: never skip past a `\n`.
                    if nxt == '\n' {
                        line_out.push(' ');
                        i += 1;
                    } else {
                        line_out.push_str("  ");
                        i += 2;
                    }
                } else {
                    if c == '\'' {
                        state = State::Normal;
                    }
                    line_out.push(' ');
                    i += 1;
                }
            }
        }
    }
    masked.push(line_out);
    comments.push(line_com);
    Lexed { raw, masked, comments }
}

/// True iff `word` occurs in `hay` delimited by non-identifier chars on
/// both sides (the `\bword\b` of the design notes, without a regex dep).
pub fn word_in(hay: &str, word: &str) -> bool {
    find_word(hay, word).is_some()
}

/// Byte offset of the first word-boundary occurrence of `word` in `hay`.
pub fn find_word(hay: &str, word: &str) -> Option<usize> {
    let hb = hay.as_bytes();
    let mut from = 0;
    while let Some(rel) = hay[from..].find(word) {
        let at = from + rel;
        let before_ok = at == 0 || !is_ident_byte(hb[at - 1]);
        let end = at + word.len();
        let after_ok = end >= hb.len() || !is_ident_byte(hb[end]);
        if before_ok && after_ok {
            return Some(at);
        }
        from = at + 1;
    }
    None
}

fn is_ident_byte(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_line_and_block_comments() {
        let l = lex("let x = 1; // unsafe here\n/* unsafe\n   block */ let y = 2;");
        assert!(!l.masked[0].contains("unsafe"));
        assert!(l.comments[0].contains("unsafe here"));
        assert!(!l.masked[1].contains("unsafe"));
        assert!(l.comments[1].contains("unsafe"));
        assert!(l.masked[2].contains("let y = 2;"));
    }

    #[test]
    fn masks_strings_and_raw_strings() {
        let l = lex("let s = \"vec! { )\"; let r = r#\"unsafe \" }\"#; done();");
        assert!(!l.masked[0].contains("vec!"));
        assert!(!l.masked[0].contains("unsafe"));
        assert!(l.masked[0].contains("done();"));
        // masking must not fabricate unbalanced brackets
        let opens = l.masked[0].matches(['(', '{']).count();
        let closes = l.masked[0].matches([')', '}']).count();
        assert_eq!(opens, closes);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let l = lex("fn f<'a>(x: &'a str) -> &'a str { x } // ok");
        assert!(l.masked[0].contains("<'a>"));
        assert!(l.masked[0].contains("&'a str"));
        let l2 = lex("let c = 'x'; let esc = '\\''; after();");
        assert!(!l2.masked[0].contains('x'));
        assert!(l2.masked[0].contains("after();"));
    }

    #[test]
    fn raw_string_hashes_inside_nested_block_comments() {
        // The `r#"…"#` inside a comment is prose, not a string: the
        // comment state machine must keep nesting, and the code after the
        // outer close must survive on the masked view.
        let l = lex("/* outer /* r#\"deep\"# */ tail */ let a = vec![1];");
        assert!(l.masked[0].contains("let a = vec![1];"));
        assert!(l.comments[0].contains("r#\"deep\"#"));
        // And the dual: a block-comment opener inside a raw string is data.
        let l2 = lex("let s = r#\"/* not a comment \"quote\" */\"#; after();");
        assert!(l2.masked[0].contains("after();"));
        assert!(!l2.masked[0].contains("not a comment"));
    }

    #[test]
    fn byte_char_literals_are_masked_not_lifetimes() {
        let l = lex("let b = b'x'; after();");
        assert!(!l.masked[0].contains('x'));
        assert!(l.masked[0].contains("after();"));
        let esc = lex("let b = b'\\''; after();");
        assert!(esc.masked[0].contains("after();"));
        // A generic lifetime and a byte char on the same line must not
        // bleed into each other.
        let both = lex("fn f<'a>(x: &'a [u8]) { let c = b'a'; g(c); }");
        assert!(both.masked[0].contains("<'a>"));
        assert!(both.masked[0].contains("g(c);"));
    }

    #[test]
    fn static_lifetime_adjacent_to_angle_bracket() {
        let l = lex("let m: Map<'static, u8> = m2; after();");
        assert!(l.masked[0].contains("<'static, u8>"));
        assert!(l.masked[0].contains("after();"));
        let bound = lex("fn g<'s>() where 's: 'static {}");
        assert!(bound.masked[0].contains("'s: 'static"));
    }

    #[test]
    fn backslash_newline_in_string_keeps_line_vectors_in_sync() {
        // A string continuation (`\` at end of line) used to swallow the
        // newline, desyncing raw vs masked/comments line counts.
        let l = lex("let s = \"line one \\\n  continued\"; after();\nlast();");
        assert_eq!(l.raw.len(), 3);
        assert_eq!(l.masked.len(), 3);
        assert_eq!(l.comments.len(), 3);
        assert!(l.masked[1].contains("after();"));
        assert!(l.masked[2].contains("last();"));
    }

    #[test]
    fn word_boundaries() {
        assert!(word_in("x unsafe {", "unsafe"));
        assert!(!word_in("make_unsafe_name()", "unsafe"));
        assert!(!word_in("unsafely()", "unsafe"));
        assert!(word_in("unsafe", "unsafe"));
    }
}

//! SARIF 2.1.0 output for CI code-scanning upload.
//!
//! Hand-rolled JSON (the tool is dependency-free by design): the schema
//! subset emitted here is the minimum GitHub code scanning consumes —
//! one run, a `tool.driver` with per-rule metadata, and one `result` per
//! finding with a `physicalLocation`. Paths are emitted exactly as
//! scanned (repo-root-relative when the tool is run from the repo root,
//! as CI does), which is what the upload action expects.

use crate::lints::Finding;

/// (rule id, short description) — one entry per lint family.
const RULES: [(&str, &str); 7] = [
    ("L1", "workspace buffer-pool acquire/release balance"),
    ("L2", "zero-alloc hygiene in annotated warm-path fns (incl. call-path closure)"),
    ("L3", "SAFETY comments on unsafe"),
    ("L4", "dispatch exhaustiveness and failpoints gating"),
    ("L5", "line length and bracket balance"),
    ("L6", "per-binding buffer dataflow (double release, leaks, kind mismatch)"),
    ("L7", "determinism (unordered collections, reduce-order annotations)"),
];

/// Escape a string for embedding in a JSON string literal.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render `findings` as a SARIF 2.1.0 document.
pub fn to_sarif(findings: &[Finding]) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"$schema\": \"https://json.schemastore.org/sarif-2.1.0.json\",\n");
    out.push_str("  \"version\": \"2.1.0\",\n");
    out.push_str("  \"runs\": [\n    {\n");
    out.push_str("      \"tool\": {\n        \"driver\": {\n");
    out.push_str("          \"name\": \"randnmf-lint\",\n");
    out.push_str(
        "          \"informationUri\": \
         \"https://example.invalid/randnmf/docs/STATIC_ANALYSIS.md\",\n",
    );
    out.push_str("          \"rules\": [\n");
    for (i, (id, desc)) in RULES.iter().enumerate() {
        out.push_str(&format!(
            "            {{\"id\": \"{id}\", \"shortDescription\": {{\"text\": \"{}\"}}}}{}\n",
            esc(desc),
            if i + 1 < RULES.len() { "," } else { "" }
        ));
    }
    out.push_str("          ]\n        }\n      },\n");
    out.push_str("      \"results\": [\n");
    for (i, f) in findings.iter().enumerate() {
        out.push_str("        {\n");
        out.push_str(&format!("          \"ruleId\": \"{}\",\n", esc(f.code)));
        out.push_str("          \"level\": \"error\",\n");
        out.push_str(&format!(
            "          \"message\": {{\"text\": \"{}\"}},\n",
            esc(&f.message)
        ));
        out.push_str("          \"locations\": [\n            {\n");
        out.push_str("              \"physicalLocation\": {\n");
        out.push_str(&format!(
            "                \"artifactLocation\": {{\"uri\": \"{}\"}},\n",
            esc(&f.path)
        ));
        out.push_str(&format!(
            "                \"region\": {{\"startLine\": {}}}\n",
            f.line
        ));
        out.push_str("              }\n            }\n          ]\n");
        out.push_str(&format!(
            "        }}{}\n",
            if i + 1 < findings.len() { "," } else { "" }
        ));
    }
    out.push_str("      ]\n    }\n  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_schema_rules_and_one_result_per_finding() {
        let findings = vec![
            Finding {
                path: "rust/src/a.rs".to_string(),
                line: 7,
                code: "L2",
                message: "fn hot: `vec!` in zero-alloc fn".to_string(),
            },
            Finding {
                path: "rust/src/b.rs".to_string(),
                line: 12,
                code: "L7",
                message: "quote \" and backslash \\ survive".to_string(),
            },
        ];
        let s = to_sarif(&findings);
        assert!(s.contains("\"version\": \"2.1.0\""));
        assert!(s.contains("\"name\": \"randnmf-lint\""));
        assert_eq!(s.matches("\"ruleId\"").count(), 2);
        assert!(s.contains("\"uri\": \"rust/src/a.rs\""));
        assert!(s.contains("\"startLine\": 7"));
        // escaping: the quote/backslash in the message must be JSON-escaped
        assert!(s.contains("quote \\\" and backslash \\\\ survive"));
        // all seven rule families are declared
        for (id, _) in RULES {
            assert!(s.contains(&format!("\"id\": \"{id}\"")));
        }
        // empty findings → an empty results array, still valid
        let empty = to_sarif(&[]);
        assert!(empty.contains("\"results\": [\n      ]"));
    }
}

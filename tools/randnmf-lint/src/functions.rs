//! Function-region and enum extraction over the masked line view.
//!
//! Brace-depth tracking on masked text gives each `fn` a body line range
//! with nested `fn` bodies excluded (each line belongs to the innermost
//! open function). Trait method *signatures* (terminated by `;` at
//! paren/bracket depth 0) produce no body. `// lint:` annotations are
//! collected from the contiguous comment/attribute block immediately
//! above the `fn` line.

use crate::lexer::{find_word, Lexed};

/// One extracted function region.
pub struct FnInfo {
    pub name: String,
    /// 0-based line of the `fn` keyword.
    pub sig_line: usize,
    /// 0-based line indices of the body (innermost-ownership: nested fn
    /// bodies belong to the nested fn, not the parent).
    pub body: Vec<usize>,
    /// `// lint: <annotation>` strings from the block above the fn.
    pub annos: Vec<String>,
}

/// An enum declaration and its variant names.
pub struct EnumInfo {
    pub name: String,
    /// 0-based line of the `enum` keyword.
    pub sig_line: usize,
    pub variants: Vec<String>,
}

/// Identifier starting at `s[at..]` (ASCII ident chars).
pub(crate) fn ident_at(s: &str, at: usize) -> &str {
    let b = s.as_bytes();
    let mut end = at;
    while end < b.len() && (b[end] == b'_' || b[end].is_ascii_alphanumeric()) {
        end += 1;
    }
    &s[at..end]
}

/// `fn <name>` on a masked line → the name (first occurrence only,
/// mirroring the validated prototype).
pub fn fn_decl_name(masked_line: &str) -> Option<String> {
    let mut base = 0;
    while let Some(rel) = find_word(&masked_line[base..], "fn") {
        let at = base + rel;
        let rest = &masked_line[at + 2..];
        let trimmed = rest.trim_start();
        // `fn` must be followed by whitespace and a name — an `fn(...)`
        // pointer type is not a declaration; keep scanning the line.
        if trimmed.len() < rest.len() {
            let name = ident_at(trimmed, 0);
            if !name.is_empty() {
                return Some(name.to_string());
            }
        }
        base = at + 2;
    }
    None
}

/// Collect `lint:` annotations from the contiguous comment/attr block
/// immediately above line `idx`.
fn parse_annotations(lx: &Lexed, idx: usize) -> Vec<String> {
    let mut annos = Vec::new();
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let com = lx.comments[j].trim();
        let code = lx.masked[j].trim();
        if !com.is_empty() && code.is_empty() {
            if let Some(at) = com.find("lint:") {
                annos.push(com[at + 5..].trim().to_string());
            }
            continue;
        }
        if code.starts_with("#[") || code.starts_with("#![") {
            continue;
        }
        break;
    }
    annos
}

/// Walk the masked lines tracking brace depth; collect every fn region.
pub fn extract_fns(lx: &Lexed) -> Vec<FnInfo> {
    let mut fns: Vec<FnInfo> = Vec::new();
    let mut stack: Vec<(FnInfo, usize)> = Vec::new(); // (fn, entry depth)
    let mut depth = 0usize;
    let mut pdepth = 0isize; // paren/bracket depth: `;` in `[u8; 8]` is no terminator
    let mut pending: Option<(FnInfo, usize)> = None;

    for (i, line) in lx.masked.iter().enumerate() {
        if pending.is_none() {
            if let Some(name) = fn_decl_name(line) {
                let info = FnInfo {
                    name,
                    sig_line: i,
                    body: Vec::new(),
                    annos: parse_annotations(lx, i),
                };
                pending = Some((info, depth));
            }
        }
        for ch in line.chars() {
            match ch {
                '(' | '[' => pdepth += 1,
                ')' | ']' => pdepth -= 1,
                '{' => {
                    if let Some((_, d)) = &pending {
                        if depth == *d {
                            let (info, d) = pending.take().expect("pending fn");
                            stack.push((info, d));
                        }
                    }
                    depth += 1;
                }
                '}' => {
                    depth = depth.saturating_sub(1);
                    if let Some((_, d)) = stack.last() {
                        if depth == *d {
                            let (mut info, _) = stack.pop().expect("open fn");
                            info.body.push(i);
                            fns.push(info);
                        }
                    }
                }
                ';' => {
                    if let Some((_, d)) = &pending {
                        if depth == *d && pdepth == 0 {
                            pending = None; // trait signature, no body
                        }
                    }
                }
                _ => {}
            }
        }
        if let Some((info, _)) = stack.last_mut() {
            info.body.push(i);
        }
    }
    fns
}

/// Collect enum declarations and their variant names (multi-line enums;
/// variant lines are `Ident,` / `Ident {` / `Ident(` / bare `Ident`).
pub fn collect_enums(lx: &Lexed) -> Vec<EnumInfo> {
    let mut enums = Vec::new();
    let mut depth = 0usize;
    // (name, decl line, entry depth, variants, body brace seen)
    let mut cur: Option<(String, usize, usize, Vec<String>, bool)> = None;

    for (i, line) in lx.masked.iter().enumerate() {
        if cur.is_none() {
            if let Some(at) = find_word(line, "enum") {
                let rest = line[at + 4..].trim_start();
                let name = ident_at(rest, 0);
                if !name.is_empty() {
                    cur = Some((name.to_string(), i, depth, Vec::new(), false));
                }
            }
        }
        for ch in line.chars() {
            match ch {
                '{' => {
                    if let Some((_, _, d, _, seen)) = &mut cur {
                        if !*seen && depth == *d {
                            *seen = true;
                        }
                    }
                    depth += 1;
                }
                '}' => {
                    depth = depth.saturating_sub(1);
                    let close = matches!(&cur, Some((_, _, d, _, true)) if depth == *d);
                    if close {
                        let (name, sig_line, _, variants, _) = cur.take().expect("open enum");
                        enums.push(EnumInfo { name, sig_line, variants });
                    }
                }
                _ => {}
            }
        }
        if let Some((_, _, _, variants, true)) = &mut cur {
            if let Some(v) = variant_name(line) {
                variants.push(v);
            }
        }
    }
    enums
}

/// `  Ident,` / `Ident {` / `Ident(` / bare `Ident` at line start (after
/// whitespace), uppercase first letter — an enum variant line.
fn variant_name(masked_line: &str) -> Option<String> {
    let t = masked_line.trim_start();
    let first = t.chars().next()?;
    if !first.is_ascii_uppercase() {
        return None;
    }
    let name = ident_at(t, 0);
    let rest = t[name.len()..].trim_start();
    match rest.chars().next() {
        None | Some(',') | Some('{') | Some('(') => Some(name.to_string()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    #[test]
    fn extracts_fn_with_annotations_and_excludes_nested() {
        let src = "\
// lint: zero-alloc
// lint: transfers-buffers: moves out
#[inline]
pub fn outer(x: usize) -> usize {
    let a = [0u8; 8];
    fn inner() -> usize {
        99
    }
    inner() + x + a.len()
}
";
        let fns = extract_fns(&lex(src));
        assert_eq!(fns.len(), 2);
        let inner = &fns[0];
        let outer = &fns[1];
        assert_eq!(inner.name, "inner");
        assert_eq!(outer.name, "outer");
        assert_eq!(outer.sig_line, 3);
        // collected closest-first walking up from the signature
        assert_eq!(
            outer.annos,
            vec!["transfers-buffers: moves out".to_string(), "zero-alloc".to_string()]
        );
        // the nested fn's body line (99) belongs to inner, not outer
        assert!(inner.body.contains(&6));
        assert!(!outer.body.contains(&6));
    }

    #[test]
    fn trait_signatures_have_no_body() {
        let src = "\
trait T {
    fn sig_only(&self, x: [u8; 8]) -> usize;
    fn with_default(&self) -> usize {
        1
    }
}
";
        let fns = extract_fns(&lex(src));
        assert_eq!(fns.len(), 1);
        assert_eq!(fns[0].name, "with_default");
    }

    #[test]
    fn collects_enum_variants() {
        let src = "\
pub enum SketchKind {
    /// docs
    Uniform,
    Gaussian,
    SparseSign { nnz: usize },
    Srht,
}
";
        let enums = collect_enums(&lex(src));
        assert_eq!(enums.len(), 1);
        assert_eq!(enums[0].name, "SketchKind");
        assert_eq!(enums[0].variants, vec!["Uniform", "Gaussian", "SparseSign", "Srht"]);
    }
}

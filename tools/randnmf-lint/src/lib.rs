//! randnmf-lint — repo-invariant static analysis for the randnmf tree.
//!
//! A self-contained, dependency-free text/token-level analyzer (no rustc
//! internals; runs on the same pinned stable toolchain as the main
//! crate). It enforces the invariants that used to live in a per-PR
//! hand-audit checklist:
//!
//! * **L1** buffer-pool discipline (`acquire_*` / `release_*` / `recycle`)
//! * **L2** zero-alloc hygiene in annotated warm-path fns, including the
//!   inter-procedural call-path closure (`callgraph`)
//! * **L3** `// SAFETY:` comments on every `unsafe`
//! * **L4** dispatch exhaustiveness over `SketchKind` / `SolverKind`,
//!   plus the failpoints feature-gating tripwire
//! * **L5** 100-column lines and comment/string-aware bracket balance
//! * **L6** per-binding buffer dataflow: double release, release before
//!   acquire, kind mismatch, early-`return`/`?` leak paths (`dataflow`)
//! * **L7** determinism: no `HashMap`/`HashSet` in numeric paths,
//!   `deterministic-reduce(<reason>)` on every split/reduce call site
//!
//! Rules, rationale, and the annotation/waiver syntax are documented in
//! `docs/STATIC_ANALYSIS.md`. Run it from the repo root:
//!
//! ```text
//! cargo run -p randnmf-lint -- rust/src rust/tests rust/benches tools
//! ```
//!
//! Exit status is 0 when the tree is clean, 1 with `path:line: [Lx] ...`
//! findings on stdout otherwise, 2 on I/O errors. `--format sarif`
//! switches stdout to a SARIF 2.1.0 document for code-scanning upload.
//!
//! Directory recursion skips subdirectories named `fixtures` — they hold
//! the intentionally-violating lint corpus. Passing such a directory as
//! an explicit root still scans it (that is how the corpus tests run).

pub mod callgraph;
pub mod dataflow;
pub mod functions;
pub mod lexer;
pub mod lints;
pub mod sarif;

pub use lints::{Finding, SourceFile, BANNED, REQUIRED_DISPATCH};
pub use sarif::to_sarif;

use std::fs;
use std::path::{Path, PathBuf};

/// Outcome of a lint run.
pub struct Report {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

/// Lint every `.rs` file under `roots` (each root may be a file or a
/// directory). Deterministic: files are visited in sorted path order.
pub fn run(roots: &[String]) -> Result<Report, String> {
    let mut files: Vec<PathBuf> = Vec::new();
    for root in roots {
        let p = Path::new(root);
        if p.is_file() {
            files.push(p.to_path_buf());
        } else if p.is_dir() {
            walk(p, &mut files)?;
        } else {
            return Err(format!("{root}: not a file or directory"));
        }
    }
    files.sort();
    files.dedup();

    let mut parsed: Vec<SourceFile> = Vec::with_capacity(files.len());
    for path in &files {
        let text = fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        parsed.push(SourceFile::parse(&path.display().to_string(), &text));
    }
    Ok(Report { findings: lints::lint(&parsed), files_scanned: files.len() })
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let mut entries: Vec<PathBuf> = Vec::new();
    for entry in rd {
        let entry = entry.map_err(|e| format!("{}: {e}", dir.display()))?;
        entries.push(entry.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            // The fixtures corpus violates the lints on purpose; it is
            // only scanned when passed as an explicit root.
            if p.file_name().is_some_and(|n| n == "fixtures") {
                continue;
            }
            walk(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}
